//! The [`SchedPolicy`] trait and the built-in scheduling disciplines.
//!
//! A policy owns two things: the total **merge order** over queued
//! tasks (an [`OrdKey`] per task, used both for within-bucket ordering
//! and the k-way merge across buckets) and the **drain discipline**
//! that walks the bucketed queue placing work. Everything else — the
//! bucket structure, taken-entry bookkeeping, compaction — is shared
//! [`ShapeQueue`] machinery, so a new discipline only implements the
//! decision logic.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::queue::{OrdKey, ShapeQueue};
use super::{Policy, QueuedTask, SchedStats, ScheduledTask};
use crate::resources::{Allocator, ResourceRequest};

/// A running task's projection, as seen by policies that reason about
/// the future (conservative backfill): when its resources come back,
/// how much of them actually return to the pool (slices on draining
/// nodes vanish instead), and which driver owns it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InFlight {
    /// Expected completion instant (engine seconds).
    pub end: f64,
    /// The portion of the task's request that will return to the free
    /// pool on completion (excludes slices on draining nodes).
    pub req: ResourceRequest,
    /// Owning driver slot.
    pub tenant: usize,
}

/// Per-round context handed to [`SchedPolicy::drain`].
#[derive(Debug, Clone, Copy)]
pub struct DrainCtx<'a> {
    /// The engine clock at this drain round.
    pub now: f64,
    /// In-flight tasks sorted by `(end, uid)` — empty unless the active
    /// policy asked for it via [`SchedPolicy::needs_projection`].
    pub running: &'a [InFlight],
}

impl DrainCtx<'static> {
    /// A context with no projection data (policies that never look at
    /// the future — everything but conservative backfill).
    pub fn at(now: f64) -> DrainCtx<'static> {
        DrainCtx { now, running: &[] }
    }
}

/// A pluggable scheduling discipline over the shape-bucketed ready
/// queue. Implementations must be deterministic: identical queue,
/// allocator and context state must produce identical placements (the
/// checkpoint/resume subsystem replays drains bit-for-bit).
pub trait SchedPolicy: std::fmt::Debug {
    /// The wire-level tag this discipline implements.
    fn kind(&self) -> Policy;

    /// Merge key for a task arriving with sequence number `seq` (see
    /// [`OrdKey`] for the comparison semantics).
    fn key(&self, t: &QueuedTask, seq: u64) -> OrdKey;

    /// One placement round: walk the queue in discipline order, place
    /// what the discipline admits, and return the placements in
    /// decision order. Entries are removed via [`ShapeQueue::take`];
    /// the caller compacts afterwards.
    fn drain(
        &mut self,
        q: &mut ShapeQueue,
        alloc: &mut Allocator,
        ctx: &DrainCtx,
        stats: &mut SchedStats,
    ) -> Vec<ScheduledTask>;

    /// Whether [`DrainCtx::running`] must be populated (building the
    /// sorted projection costs O(in-flight log in-flight) per round, so
    /// it is only done for policies that use it).
    fn needs_projection(&self) -> bool {
        false
    }

    /// A task of `tenant` started running (usage accounting hook).
    fn task_started(&mut self, _tenant: usize, _req: &ResourceRequest) {}

    /// A running task of `tenant` finished (usage accounting hook).
    fn task_finished(&mut self, _tenant: usize, _req: &ResourceRequest) {}

    /// Set a tenant's fair-share weight (no-op for unweighted policies).
    fn set_weight(&mut self, _tenant: usize, _weight: f64) {}

    /// Non-default `(tenant, weight)` pairs, ascending by tenant —
    /// checkpoint capture: replaying them through
    /// [`set_weight`](Self::set_weight) on a fresh discipline restores
    /// the weighting exactly. Weightless policies report none.
    fn weights(&self) -> Vec<(usize, f64)> {
        Vec::new()
    }
}

/// FIFO by submission time. With `strict = true` the queue head blocks
/// everything behind it (no backfill); otherwise later tasks that fit
/// are placed past a blocked head (RADICAL-Pilot-like aggressive
/// backfill — the default discipline).
#[derive(Debug, Clone, Copy)]
pub struct Fifo {
    pub strict: bool,
}

impl SchedPolicy for Fifo {
    fn kind(&self) -> Policy {
        if self.strict {
            Policy::FifoStrict
        } else {
            Policy::FifoBackfill
        }
    }

    fn key(&self, t: &QueuedTask, seq: u64) -> OrdKey {
        OrdKey { major: 0, time: t.submitted_at, seq }
    }

    fn drain(
        &mut self,
        q: &mut ShapeQueue,
        alloc: &mut Allocator,
        _ctx: &DrainCtx,
        stats: &mut SchedStats,
    ) -> Vec<ScheduledTask> {
        drain_greedy(q, alloc, self.strict, stats)
    }
}

/// Order by `(priority, submit time)`; the engine sets priority =
/// pipeline index, so older pipelines always win. Tempting, but it
/// starves younger pipelines' stragglers — kept as an ablation.
#[derive(Debug, Clone, Copy)]
pub struct PipelineAge;

impl SchedPolicy for PipelineAge {
    fn kind(&self) -> Policy {
        Policy::PipelineAge
    }

    fn key(&self, t: &QueuedTask, seq: u64) -> OrdKey {
        OrdKey { major: t.priority, time: t.submitted_at, seq }
    }

    fn drain(
        &mut self,
        q: &mut ShapeQueue,
        alloc: &mut Allocator,
        _ctx: &DrainCtx,
        stats: &mut SchedStats,
    ) -> Vec<ScheduledTask> {
        drain_greedy(q, alloc, false, stats)
    }
}

/// Shortest-job-first by requested size (greedy packing ablation).
#[derive(Debug, Clone, Copy)]
pub struct SmallestFirst;

impl SchedPolicy for SmallestFirst {
    fn kind(&self) -> Policy {
        Policy::SmallestFirst
    }

    fn key(&self, t: &QueuedTask, seq: u64) -> OrdKey {
        OrdKey {
            major: t.req.cpu_cores as u64 + 100 * t.req.gpus as u64,
            time: 0.0,
            seq,
        }
    }

    fn drain(
        &mut self,
        q: &mut ShapeQueue,
        alloc: &mut Allocator,
        _ctx: &DrainCtx,
        stats: &mut SchedStats,
    ) -> Vec<ScheduledTask> {
        drain_greedy(q, alloc, false, stats)
    }
}

/// Conservative (EASY-style) backfill: FIFO order, but once the queue
/// head is blocked the scheduler computes the head's *projected start*
/// — the earliest instant the in-flight releases cover its request —
/// and admits later tasks **only if they cannot delay it**: either they
/// finish before the projected start, or they fit inside the spare
/// resources the head will not need.
///
/// Two deliberate approximations keep the round O(shapes):
///
/// - projection is at free-vector granularity (node-local fragmentation
///   is invisible to it), so a projected start is a lower bound;
/// - per shape, only the FIFO-earliest task is a backfill candidate in
///   a given round (later same-shape tasks wait their turn).
///
/// Both err toward *not* delaying the head, never toward starving it.
/// A head that no in-flight release can ever satisfy (it needs a grow)
/// yields an unbounded projection and the round degenerates to
/// aggressive backfill — there is nothing to protect.
#[derive(Debug, Clone, Copy)]
pub struct Backfill;

#[derive(Debug, Clone, Copy)]
struct Reservation {
    /// The blocked head's projected start.
    at: f64,
    /// Resources still free at `at` after the head hypothetically
    /// starts — what long-running backfill may consume.
    spare_cores: u64,
    spare_gpus: u64,
}

impl Backfill {
    fn reserve(head: &ResourceRequest, alloc: &Allocator, ctx: &DrainCtx) -> Reservation {
        let need_c = head.cpu_cores as u64;
        let need_g = head.gpus as u64;
        let (mut fc, mut fg) = (alloc.free_cores(), alloc.free_gpus());
        if fc >= need_c && fg >= need_g {
            // Vector-level the head fits now (node-local fragmentation
            // blocked it): projected start is "immediately", spare is
            // whatever the vector says is left over.
            return Reservation {
                at: ctx.now,
                spare_cores: fc - need_c,
                spare_gpus: fg - need_g,
            };
        }
        for r in ctx.running {
            fc += r.req.cpu_cores as u64;
            fg += r.req.gpus as u64;
            if fc >= need_c && fg >= need_g {
                return Reservation {
                    at: r.end,
                    spare_cores: fc - need_c,
                    spare_gpus: fg - need_g,
                };
            }
        }
        // No release schedule ever satisfies the head (it waits for a
        // grow): nothing to reserve against.
        Reservation { at: f64::INFINITY, spare_cores: u64::MAX, spare_gpus: u64::MAX }
    }
}

impl SchedPolicy for Backfill {
    fn kind(&self) -> Policy {
        Policy::Backfill
    }

    fn key(&self, t: &QueuedTask, seq: u64) -> OrdKey {
        OrdKey { major: 0, time: t.submitted_at, seq }
    }

    fn needs_projection(&self) -> bool {
        true
    }

    fn drain(
        &mut self,
        q: &mut ShapeQueue,
        alloc: &mut Allocator,
        ctx: &DrainCtx,
        stats: &mut SchedStats,
    ) -> Vec<ScheduledTask> {
        // Seed with every bucket head: the *globally* first blocked
        // task defines the reservation, so no bucket may be screened
        // out before it is found.
        let mut heap: BinaryHeap<Reverse<(OrdKey, usize, usize)>> = BinaryHeap::new();
        for b in q.bucket_ids() {
            let idx = q.first_live(b).expect("bucket_ids yields live buckets");
            heap.push(Reverse((q.key_at(b, idx), b, idx)));
        }
        let mut placed = Vec::new();
        let mut reservation: Option<Reservation> = None;
        while let Some(Reverse((_, b, idx))) = heap.pop() {
            stats.tasks_examined += 1;
            let task = *q.task_at(b, idx);
            let admitted = match &reservation {
                None => true,
                Some(res) => {
                    ctx.now + task.est <= res.at + 1e-9
                        || (task.req.cpu_cores as u64 <= res.spare_cores
                            && task.req.gpus as u64 <= res.spare_gpus)
                }
            };
            if !admitted {
                // This shape's earliest task would delay the head;
                // the whole bucket sits the round out.
                stats.shape_probes += 1;
                continue;
            }
            match alloc.try_alloc(&task.req) {
                Some(placement) => {
                    if let Some(res) = &mut reservation {
                        // A backfill running past the projected start
                        // consumes spare capacity the head must not
                        // need; one finishing before it consumes none.
                        if ctx.now + task.est > res.at + 1e-9 {
                            res.spare_cores -= task.req.cpu_cores as u64;
                            res.spare_gpus -= task.req.gpus as u64;
                        }
                    }
                    q.take(b, idx);
                    placed.push(ScheduledTask { uid: task.uid, placement, task });
                    if let Some(n) = q.next_live(b, idx) {
                        heap.push(Reverse((q.key_at(b, n), b, n)));
                    }
                }
                None => {
                    stats.shape_probes += 1;
                    if reservation.is_none() {
                        reservation = Some(Backfill::reserve(&task.req, alloc, ctx));
                    }
                    // Bucket blocked for the round (same shape cannot
                    // fit later: the allocation only shrinks).
                }
            }
        }
        placed
    }
}

/// Shared greedy walk: visit bucket heads in merge-key order, place
/// everything that fits. `strict` stops the round at the first task
/// that does not fit (head-of-line blocking); otherwise a failed shape
/// blocks only its own bucket — the bucketed replacement for the old
/// failed-shape memo, O(shapes) on a fully-blocked queue.
pub(crate) fn drain_greedy(
    q: &mut ShapeQueue,
    alloc: &mut Allocator,
    strict: bool,
    stats: &mut SchedStats,
) -> Vec<ScheduledTask> {
    let mut heap: BinaryHeap<Reverse<(OrdKey, usize, usize)>> = BinaryHeap::new();
    for b in q.bucket_ids() {
        stats.shape_probes += 1;
        // Cheap vector screen — except under strict ordering, where a
        // screened-out *head* must still be discovered in merge order
        // so it can stop the round.
        if !strict && !alloc.may_fit(&q.shape(b)) {
            continue;
        }
        let idx = q.first_live(b).expect("bucket_ids yields live buckets");
        heap.push(Reverse((q.key_at(b, idx), b, idx)));
    }
    let mut placed = Vec::new();
    while let Some(Reverse((_, b, idx))) = heap.pop() {
        stats.tasks_examined += 1;
        let task = *q.task_at(b, idx);
        match alloc.try_alloc(&task.req) {
            Some(placement) => {
                q.take(b, idx);
                placed.push(ScheduledTask { uid: task.uid, placement, task });
                if let Some(n) = q.next_live(b, idx) {
                    heap.push(Reverse((q.key_at(b, n), b, n)));
                }
            }
            None => {
                stats.shape_probes += 1;
                if strict {
                    break;
                }
                // Bucket blocked for the rest of the round.
            }
        }
    }
    placed
}
