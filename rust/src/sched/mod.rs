//! Pluggable scheduling subsystem (substrate S13): a shape-bucketed
//! ready queue under a [`SchedPolicy`] trait.
//!
//! This replaces the old monolithic `pilot::scheduler`. Two structural
//! ideas:
//!
//! - **Shape bucketing** ([`ShapeQueue`]): queued tasks are indexed by
//!   resource shape `(cores, gpus)`. Within one drain round the
//!   allocation only shrinks, so a shape that failed to place once can
//!   never place later in the round — a blocked *bucket* is skipped
//!   wholesale, making a fully-blocked round O(shapes) instead of
//!   O(queue). Per-bucket ordering plus a k-way merge reproduces the
//!   old flat-queue policy order bit-for-bit (property-tested against
//!   a reference implementation in `tests/sched_equiv.rs`).
//! - **Policy pluggability** ([`SchedPolicy`]): the drain discipline is
//!   a trait object selected per run via [`Policy`]. Besides the
//!   classic FIFO(+backfill) family, two disciplines target the
//!   streaming-coordinator workload: [`WeightedFair`] (per-driver
//!   dominant-resource fair sharing, so one greedy campaign member
//!   cannot starve late arrivals) and [`Backfill`] (conservative
//!   backfill that never delays a blocked head's projected start).
//!
//! Determinism is a hard contract: every discipline produces identical
//! placements from identical state, which is what lets the checkpoint
//! subsystem resume a preempted run bit-identically under any policy.
//!
//! # Examples
//!
//! ```
//! use asyncflow::resources::{Allocator, ClusterSpec, ResourceRequest};
//! use asyncflow::sched::{DrainCtx, Policy, QueuedTask, Scheduler};
//!
//! let mut s = Scheduler::new(Policy::FifoBackfill);
//! for uid in 0..3 {
//!     s.push(QueuedTask {
//!         uid,
//!         req: ResourceRequest::new(2, 0),
//!         priority: 0,
//!         submitted_at: uid as f64,
//!         tenant: 0,
//!         est: 10.0,
//!     });
//! }
//! let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 4, 0));
//! let placed = s.drain_schedulable(&mut alloc, &DrainCtx::at(0.0));
//! assert_eq!(placed.len(), 2, "4 cores fit two 2-core tasks");
//! assert_eq!(s.queue_len(), 1);
//! assert_eq!(s.queued_demand(), (2, 0));
//! ```

mod fair;
mod policy;
mod queue;

pub use fair::WeightedFair;
pub use policy::{Backfill, DrainCtx, Fifo, InFlight, PipelineAge, SchedPolicy, SmallestFirst};
pub use queue::{OrdKey, ShapeQueue};

use crate::error::{Error, Result};
use crate::resources::{Allocator, Placement, ResourceRequest};
use crate::util::json::{from_u64, obj, FromJson, Json, ToJson};

/// Scheduling disciplines (selected per run; `--policy` on the CLI).
///
/// # Examples
///
/// ```
/// use asyncflow::sched::Policy;
///
/// let p: Policy = "fair".parse().unwrap();
/// assert_eq!(p, Policy::WeightedFair);
/// assert_eq!(p.label(), "weighted_fair");
/// assert_eq!("backfill".parse::<Policy>().unwrap(), Policy::Backfill);
/// assert_eq!("fifo".parse::<Policy>().unwrap(), Policy::FifoBackfill);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Policy {
    /// Order by (priority, submit time, uid); the engine sets priority =
    /// pipeline index, so older pipelines always win. Tempting, but it
    /// starves younger pipelines' stragglers (an old pipeline's 96-task
    /// Inference set trickles through GPUs one-by-one ahead of the last
    /// task of a younger Simulation set) — kept as an ablation.
    PipelineAge,
    /// FIFO by submission time with aggressive backfill — RADICAL-
    /// Pilot-like and the default: it reproduces the paper's masking
    /// behaviour.
    #[default]
    FifoBackfill,
    /// Pure FIFO, **no** backfill: the head of the queue blocks everyone
    /// behind it (worst case for masking; ablation baseline).
    FifoStrict,
    /// Shortest-job-first by requested cores (greedy packing).
    SmallestFirst,
    /// Per-driver weighted fair sharing via dominant-resource usage
    /// accounting: the next free slot goes to the driver with the
    /// lowest running share, so a greedy campaign member cannot starve
    /// late arrivals (see [`WeightedFair`]).
    WeightedFair,
    /// Conservative backfill: small tasks may jump a blocked head only
    /// when they cannot delay its projected start (see [`Backfill`]).
    Backfill,
}

impl Policy {
    /// Stable wire name (configs, checkpoints).
    pub fn label(&self) -> &'static str {
        match self {
            Policy::PipelineAge => "pipeline_age",
            Policy::FifoBackfill => "fifo_backfill",
            Policy::FifoStrict => "fifo_strict",
            Policy::SmallestFirst => "smallest_first",
            Policy::WeightedFair => "weighted_fair",
            Policy::Backfill => "backfill",
        }
    }

    /// Instantiate the discipline implementing this policy.
    pub fn build(&self) -> Box<dyn SchedPolicy> {
        match self {
            Policy::PipelineAge => Box::new(PipelineAge),
            Policy::FifoBackfill => Box::new(Fifo { strict: false }),
            Policy::FifoStrict => Box::new(Fifo { strict: true }),
            Policy::SmallestFirst => Box::new(SmallestFirst),
            Policy::WeightedFair => Box::new(WeightedFair::new()),
            Policy::Backfill => Box::new(Backfill),
        }
    }
}

impl std::str::FromStr for Policy {
    type Err = Error;
    fn from_str(s: &str) -> Result<Policy> {
        match s {
            "pipeline_age" => Ok(Policy::PipelineAge),
            "fifo" | "fifo_backfill" => Ok(Policy::FifoBackfill),
            "fifo_strict" => Ok(Policy::FifoStrict),
            "smallest_first" => Ok(Policy::SmallestFirst),
            "fair" | "weighted_fair" => Ok(Policy::WeightedFair),
            "backfill" | "conservative_backfill" => Ok(Policy::Backfill),
            other => Err(Error::Config(format!("unknown scheduler policy '{other}'"))),
        }
    }
}

/// A task waiting for resources.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueuedTask {
    pub uid: usize,
    pub req: ResourceRequest,
    pub priority: u64,
    pub submitted_at: f64,
    /// Owning driver slot — the fair-share accounting unit.
    pub tenant: usize,
    /// Expected service time (sampled TX plus launch overhead) — the
    /// conservative-backfill projection input.
    pub est: f64,
}

impl ToJson for QueuedTask {
    fn to_json(&self) -> Json {
        obj([
            ("uid", Json::from(self.uid)),
            ("req", self.req.to_json()),
            ("priority", from_u64(self.priority)),
            ("submitted_at", Json::from(self.submitted_at)),
            ("tenant", Json::from(self.tenant)),
            ("est", Json::from(self.est)),
        ])
    }
}

impl FromJson for QueuedTask {
    fn from_json(v: &Json) -> Result<QueuedTask> {
        Ok(QueuedTask {
            uid: v.req_u64("uid")? as usize,
            req: ResourceRequest::from_json(v.get("req"))?,
            priority: v.req_u64("priority")?,
            submitted_at: v.req_f64("submitted_at")?,
            tenant: v.req_u64("tenant")? as usize,
            est: v.req_f64("est")?,
        })
    }
}

/// A task the scheduler just placed.
#[derive(Debug, Clone)]
pub struct ScheduledTask {
    pub uid: usize,
    pub placement: Placement,
    /// The queue entry that was placed (tenant / request / service
    /// estimate — the agent's running-task bookkeeping).
    pub task: QueuedTask,
}

/// Drain-round accounting: what the bucketed queue actually did, per
/// scheduler lifetime. The headline probe is `shape_probes` vs
/// `tasks_examined` — on a fully-blocked round the former grows by the
/// number of distinct shapes while the latter stays put, which is the
/// whole point of bucketing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SchedStats {
    /// Drain rounds executed.
    pub rounds: u64,
    /// Shape-granular fit probes: the per-bucket screen plus every
    /// failed placement attempt that blocked a bucket.
    pub shape_probes: u64,
    /// Queue entries actually visited (placement attempts + admission
    /// checks) — the replacement for the retired sort counter.
    pub tasks_examined: u64,
}

/// Ready-queue + placement loop: a [`ShapeQueue`] drained by the
/// discipline selected via [`Policy`] (see the module docs and
/// [`SchedPolicy`] for the extension seam).
#[derive(Debug)]
pub struct Scheduler {
    policy: Policy,
    discipline: Box<dyn SchedPolicy>,
    queue: ShapeQueue,
    stats: SchedStats,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Scheduler {
        Scheduler {
            policy,
            discipline: policy.build(),
            queue: ShapeQueue::new(),
            stats: SchedStats::default(),
        }
    }

    /// The wire-level policy tag this scheduler runs.
    pub fn policy(&self) -> Policy {
        self.policy
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// The queued tasks in insertion order (checkpoint snapshots;
    /// re-pushing them into a fresh scheduler in this order reproduces
    /// the buckets, including every tie-break).
    pub fn queued(&self) -> Vec<QueuedTask> {
        self.queue.queued()
    }

    /// Total `(cores, gpus)` requested by the queued tasks — O(1), the
    /// queue maintains it incrementally (the autoscaler probes this
    /// every evaluation).
    pub fn queued_demand(&self) -> (u64, u64) {
        self.queue.demand()
    }

    /// Lifetime drain accounting (see [`SchedStats`]).
    pub fn stats(&self) -> SchedStats {
        self.stats
    }

    /// Number of distinct resource shapes currently queued.
    pub fn shape_count(&self) -> usize {
        self.queue.shape_count()
    }

    /// Whether drains need [`DrainCtx::running`] populated (the
    /// conservative-backfill projection).
    pub fn needs_projection(&self) -> bool {
        self.discipline.needs_projection()
    }

    pub fn push(&mut self, t: QueuedTask) {
        let d = &self.discipline;
        self.queue.push(t, |task, seq| d.key(task, seq));
    }

    /// Walk the queue in policy order placing what fits; remove placed
    /// entries. With [`Policy::FifoStrict`] the walk stops at the first
    /// task that does not fit. `ctx` carries the engine clock and (for
    /// projection policies) the in-flight view — [`DrainCtx::at`] for
    /// callers without one.
    pub fn drain_schedulable(
        &mut self,
        alloc: &mut Allocator,
        ctx: &DrainCtx,
    ) -> Vec<ScheduledTask> {
        self.stats.rounds += 1;
        if self.queue.is_empty() {
            return Vec::new();
        }
        let placed = self.discipline.drain(&mut self.queue, alloc, ctx, &mut self.stats);
        self.queue.finish_round();
        for s in &placed {
            self.discipline.task_started(s.task.tenant, &s.task.req);
        }
        placed
    }

    /// Record an externally-started task (checkpoint restore re-claims
    /// in-flight placements without a drain round).
    pub fn note_started(&mut self, tenant: usize, req: &ResourceRequest) {
        self.discipline.task_started(tenant, req);
    }

    /// Release a running task from the usage accounting (its resources
    /// return to the allocator separately).
    pub fn note_finished(&mut self, tenant: usize, req: &ResourceRequest) {
        self.discipline.task_finished(tenant, req);
    }

    /// Set a tenant's fair-share weight (no-op under unweighted
    /// policies). Weights are part of the run's state: checkpoints
    /// capture them via [`tenant_weights`](Self::tenant_weights) and
    /// restore replays them, so a weighted run resumes bit-identically.
    pub fn set_weight(&mut self, tenant: usize, weight: f64) {
        self.discipline.set_weight(tenant, weight);
    }

    /// Non-default `(tenant, weight)` pairs (checkpoint capture; see
    /// [`set_weight`](Self::set_weight)).
    pub fn tenant_weights(&self) -> Vec<(usize, f64)> {
        self.discipline.weights()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::ClusterSpec;

    fn qt(uid: usize, cores: u32, gpus: u32, prio: u64, at: f64) -> QueuedTask {
        QueuedTask {
            uid,
            req: ResourceRequest::new(cores, gpus),
            priority: prio,
            submitted_at: at,
            tenant: 0,
            est: 10.0,
        }
    }

    fn drain(s: &mut Scheduler, alloc: &mut Allocator) -> Vec<ScheduledTask> {
        s.drain_schedulable(alloc, &DrainCtx::at(0.0))
    }

    #[test]
    fn pipeline_age_orders_by_priority() {
        let mut s = Scheduler::new(Policy::PipelineAge);
        s.push(qt(0, 1, 0, 2, 0.0));
        s.push(qt(1, 1, 0, 0, 5.0));
        s.push(qt(2, 1, 0, 1, 1.0));
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 8, 0));
        let placed = drain(&mut s, &mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![1, 2, 0]);
    }

    #[test]
    fn fifo_strict_blocks_behind_head() {
        let mut s = Scheduler::new(Policy::FifoStrict);
        s.push(qt(0, 8, 0, 0, 0.0)); // fills the node
        s.push(qt(1, 16, 0, 0, 1.0)); // can never fit now
        s.push(qt(2, 1, 0, 0, 2.0)); // would fit, but strictly blocked
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 2, 8, 0));
        let placed = drain(&mut s, &mut alloc);
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].uid, 0);
        assert_eq!(s.queue_len(), 2);
    }

    #[test]
    fn fifo_backfill_skips_blocked_head() {
        let mut s = Scheduler::new(Policy::FifoBackfill);
        s.push(qt(0, 8, 0, 0, 0.0));
        s.push(qt(1, 16, 0, 0, 1.0));
        s.push(qt(2, 1, 0, 0, 2.0));
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 2, 8, 0));
        let placed = drain(&mut s, &mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![0, 2]);
    }

    #[test]
    fn smallest_first_packs_greedily() {
        let mut s = Scheduler::new(Policy::SmallestFirst);
        s.push(qt(0, 6, 0, 0, 0.0));
        s.push(qt(1, 1, 0, 0, 1.0));
        s.push(qt(2, 3, 0, 0, 2.0));
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 4, 0));
        let placed = drain(&mut s, &mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![1, 2]); // 1+3 cores; the 6-core task waits
    }

    #[test]
    fn fifo_out_of_order_pushes_still_sorted() {
        // Pushing an earlier submit time after a later one must fall
        // back to the true FIFO order (binary insert into the bucket).
        let mut s = Scheduler::new(Policy::FifoBackfill);
        s.push(qt(0, 1, 0, 0, 5.0));
        s.push(qt(1, 1, 0, 0, 1.0)); // earlier, pushed later
        s.push(qt(2, 1, 0, 0, 3.0));
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 3, 0));
        let placed = drain(&mut s, &mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![1, 2, 0]);
    }

    #[test]
    fn blocked_shapes_cost_one_probe_not_one_scan_per_task() {
        // 3 identical big tasks that cannot fit plus one small one: the
        // small one still backfills, and the blocked shape is probed
        // once per round — not once per task (the bucketed replacement
        // for the old failed-shape memo).
        let mut s = Scheduler::new(Policy::FifoBackfill);
        for uid in 0..3 {
            s.push(qt(uid, 16, 0, 0, uid as f64));
        }
        s.push(qt(9, 1, 0, 0, 9.0));
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 8, 0));
        let placed = drain(&mut s, &mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![9]);
        assert_eq!(s.queue_len(), 3);
        let after_first = s.stats();
        assert_eq!(
            after_first.tasks_examined, 1,
            "only the placed task is examined; the blocked shape dies at the screen"
        );
        // A fully-blocked follow-up round examines nothing at all: the
        // screen rejects the lone remaining shape in O(shapes).
        let placed = drain(&mut s, &mut alloc);
        assert!(placed.is_empty());
        let after_second = s.stats();
        assert_eq!(after_second.tasks_examined, after_first.tasks_examined);
        assert_eq!(after_second.shape_probes, after_first.shape_probes + 1);
    }

    #[test]
    fn saturated_round_is_o_shapes() {
        // 1000 tasks over 4 shapes against a full allocator: the round
        // must touch 4 buckets, not 1000 entries.
        let mut s = Scheduler::new(Policy::FifoBackfill);
        for uid in 0..1000 {
            let cores = [2u32, 3, 5, 7][uid % 4];
            s.push(qt(uid, cores, 0, 0, uid as f64));
        }
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 8, 0));
        let hog = alloc.try_alloc(&ResourceRequest::new(8, 0)).unwrap();
        let placed = drain(&mut s, &mut alloc);
        assert!(placed.is_empty());
        let st = s.stats();
        assert_eq!(s.shape_count(), 4);
        assert_eq!(st.tasks_examined, 0, "screen kills every bucket");
        assert_eq!(st.shape_probes, 4);
        // Free the hog: the next round places in FIFO order again.
        alloc.release(&hog);
        let placed = drain(&mut s, &mut alloc);
        assert_eq!(placed[0].uid, 0, "FIFO head places first");
    }

    #[test]
    fn noop_drain_leaves_queue_untouched() {
        // A drain that places nothing must not rebuild the queue — the
        // common case for a blocked queue under sustained load.
        let mut s = Scheduler::new(Policy::FifoBackfill);
        for uid in 0..4 {
            s.push(qt(uid, 16, 0, 0, uid as f64)); // none fit on 8 cores
        }
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 8, 0));
        let placed = drain(&mut s, &mut alloc);
        assert!(placed.is_empty());
        assert_eq!(s.queue_len(), 4);
        assert_eq!(s.queued_demand(), (64, 0));
    }

    #[test]
    fn deterministic_tie_break() {
        // Identical priorities/timestamps: arrival order wins, stably.
        let mut s = Scheduler::new(Policy::PipelineAge);
        for uid in 0..5 {
            s.push(qt(uid, 1, 0, 0, 0.0));
        }
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 5, 0));
        let placed = drain(&mut s, &mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn queued_round_trips_through_a_fresh_scheduler() {
        // The checkpoint contract: re-pushing queued() into a fresh
        // scheduler reproduces the drain order exactly.
        let mut s = Scheduler::new(Policy::FifoBackfill);
        s.push(qt(0, 2, 0, 0, 5.0));
        s.push(qt(1, 1, 0, 0, 1.0));
        s.push(qt(2, 2, 0, 0, 3.0));
        let mut copy = Scheduler::new(Policy::FifoBackfill);
        for t in s.queued() {
            copy.push(t);
        }
        let mut a1 = Allocator::new(&ClusterSpec::uniform("t", 1, 8, 0));
        let mut a2 = Allocator::new(&ClusterSpec::uniform("t", 1, 8, 0));
        let u1: Vec<usize> = drain(&mut s, &mut a1).iter().map(|p| p.uid).collect();
        let u2: Vec<usize> = drain(&mut copy, &mut a2).iter().map(|p| p.uid).collect();
        assert_eq!(u1, u2);
        assert_eq!(u1, vec![1, 2, 0]);
    }

    // ----- conservative backfill --------------------------------------

    #[test]
    fn backfill_admits_short_jumpers_and_protects_the_head() {
        // 4 cores; a 2-core task runs until t = 100. Head needs all 4
        // cores -> projected start 100. A 1-core 10 s task behind it
        // finishes by then: admitted. A 1-core 200 s task would hold a
        // core past t = 100 and delay the head: denied (aggressive
        // FifoBackfill would admit both).
        let cluster = ClusterSpec::uniform("t", 1, 4, 0);
        let run = |policy: Policy| {
            let mut alloc = Allocator::new(&cluster);
            alloc.try_alloc(&ResourceRequest::new(2, 0)).unwrap();
            let mut s = Scheduler::new(policy);
            s.push(QueuedTask {
                uid: 0,
                req: ResourceRequest::new(4, 0),
                priority: 0,
                submitted_at: 0.0,
                tenant: 0,
                est: 50.0,
            });
            s.push(QueuedTask {
                uid: 1,
                req: ResourceRequest::new(1, 0),
                priority: 0,
                submitted_at: 1.0,
                tenant: 0,
                est: 10.0,
            });
            s.push(QueuedTask {
                uid: 2,
                req: ResourceRequest::new(1, 0),
                priority: 0,
                submitted_at: 2.0,
                tenant: 0,
                est: 200.0,
            });
            let running = [InFlight {
                end: 100.0,
                req: ResourceRequest::new(2, 0),
                tenant: 0,
            }];
            let ctx = DrainCtx { now: 0.0, running: &running };
            s.drain_schedulable(&mut alloc, &ctx)
                .iter()
                .map(|p| p.uid)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(Policy::Backfill), vec![1], "only the short task may jump");
        assert_eq!(
            run(Policy::FifoBackfill),
            vec![1, 2],
            "aggressive backfill admits the long one too"
        );
    }

    #[test]
    fn backfill_spare_capacity_admits_long_tasks_the_head_does_not_need() {
        // 4 cores, 1 busy until t = 100. Head needs 2 cores: projected
        // start is "now" at vector level... make head GPU-blocked
        // instead: 1 node, 1 GPU busy until 100. Head needs the GPU;
        // a long CPU-only task consumes cores the head never needs ->
        // spare-capacity admission.
        let cluster = ClusterSpec::uniform("t", 1, 4, 1);
        let mut alloc = Allocator::new(&cluster);
        alloc.try_alloc(&ResourceRequest::new(1, 1)).unwrap();
        let mut s = Scheduler::new(Policy::Backfill);
        s.push(QueuedTask {
            uid: 0,
            req: ResourceRequest::new(1, 1),
            priority: 0,
            submitted_at: 0.0,
            tenant: 0,
            est: 50.0,
        });
        s.push(QueuedTask {
            uid: 1,
            req: ResourceRequest::new(2, 0),
            priority: 0,
            submitted_at: 1.0,
            tenant: 0,
            est: 500.0, // far past the projected start
        });
        let running =
            [InFlight { end: 100.0, req: ResourceRequest::new(1, 1), tenant: 0 }];
        let ctx = DrainCtx { now: 0.0, running: &running };
        let placed = s.drain_schedulable(&mut alloc, &ctx);
        assert_eq!(placed.len(), 1);
        assert_eq!(
            placed[0].uid, 1,
            "long CPU task fits the spare (head only contends on the GPU)"
        );
    }

    #[test]
    fn backfill_with_unsatisfiable_head_degenerates_to_aggressive() {
        // The head wants more cores than the inventory will ever hold:
        // there is no projected start to protect, so backfill admits
        // everything that fits (and the engine's deadlock detection
        // owns surfacing the stuck head).
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 4, 0));
        let mut s = Scheduler::new(Policy::Backfill);
        s.push(qt(0, 16, 0, 0, 0.0));
        s.push(qt(1, 1, 0, 0, 1.0));
        let ctx = DrainCtx { now: 0.0, running: &[] };
        let placed = s.drain_schedulable(&mut alloc, &ctx);
        assert_eq!(placed.len(), 1);
        assert_eq!(placed[0].uid, 1);
    }

    // ----- weighted fair sharing --------------------------------------

    #[test]
    fn fair_gives_the_free_slot_to_the_starved_tenant() {
        let mut s = Scheduler::new(Policy::WeightedFair);
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 4, 0));
        let mk = |uid: usize, tenant: usize, at: f64| QueuedTask {
            uid,
            req: ResourceRequest::new(1, 0),
            priority: 0,
            submitted_at: at,
            tenant,
            est: 10.0,
        };
        // Tenant 0 floods the queue first; tenant 1 arrives later.
        for uid in 0..8 {
            s.push(mk(uid, 0, uid as f64));
        }
        s.push(mk(100, 1, 50.0));
        let placed = drain(&mut s, &mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        // First pick: both tenants at share 0, lower tenant id wins one
        // core; then tenant 1 (still 0 running... it got one) — after
        // each placement shares move, so the 4 cores split 3 / 1 or
        // 2 / 2 depending on tie-breaks. The invariant that matters:
        // tenant 1's task is NOT last despite being submitted last.
        assert!(uids.contains(&100), "late tenant must be served in round one");
        assert!(
            uids.iter().position(|&u| u == 100).unwrap() < placed.len() - 1
                || placed.len() == 1,
            "fair share must not leave the late tenant for last: {uids:?}"
        );
        // FIFO control: the late tenant IS served last.
        let mut f = Scheduler::new(Policy::FifoBackfill);
        for uid in 0..8 {
            f.push(mk(uid, 0, uid as f64));
        }
        f.push(mk(100, 1, 50.0));
        let mut alloc2 = Allocator::new(&ClusterSpec::uniform("t", 1, 4, 0));
        let fifo_uids: Vec<usize> = drain(&mut f, &mut alloc2).iter().map(|p| p.uid).collect();
        assert_eq!(fifo_uids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn fair_weights_tilt_the_split() {
        let mut s = Scheduler::new(Policy::WeightedFair);
        s.set_weight(0, 3.0);
        s.set_weight(1, 1.0);
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 4, 0));
        for uid in 0..8 {
            s.push(QueuedTask {
                uid,
                req: ResourceRequest::new(1, 0),
                priority: 0,
                submitted_at: 0.0,
                tenant: uid % 2,
                est: 10.0,
            });
        }
        let placed = drain(&mut s, &mut alloc);
        let t0 = placed.iter().filter(|p| p.task.tenant == 0).count();
        let t1 = placed.len() - t0;
        assert_eq!(placed.len(), 4);
        assert_eq!((t0, t1), (3, 1), "3:1 weights split 4 cores 3/1");
    }

    #[test]
    fn fair_weights_round_trip_for_checkpoints() {
        // The checkpoint contract for weighted runs: capturing
        // tenant_weights() and replaying them through set_weight on a
        // fresh scheduler reproduces the drain behaviour exactly.
        let mut s = Scheduler::new(Policy::WeightedFair);
        s.set_weight(0, 3.0);
        s.set_weight(2, 0.5);
        assert_eq!(s.tenant_weights(), vec![(0, 3.0), (2, 0.5)]);
        let mut copy = Scheduler::new(Policy::WeightedFair);
        for (t, w) in s.tenant_weights() {
            copy.set_weight(t, w);
        }
        assert_eq!(copy.tenant_weights(), s.tenant_weights());
        for uid in 0..8 {
            let t = qt(uid, 1, 0, 0, 0.0);
            s.push(QueuedTask { tenant: uid % 2, ..t });
            copy.push(QueuedTask { tenant: uid % 2, ..t });
        }
        let mut a1 = Allocator::new(&ClusterSpec::uniform("t", 1, 4, 0));
        let mut a2 = Allocator::new(&ClusterSpec::uniform("t", 1, 4, 0));
        let u1: Vec<usize> = drain(&mut s, &mut a1).iter().map(|p| p.uid).collect();
        let u2: Vec<usize> = drain(&mut copy, &mut a2).iter().map(|p| p.uid).collect();
        assert_eq!(u1, u2, "replayed weights must reproduce the drain");
        // An unweighted policy reports no weights to capture.
        let f = Scheduler::new(Policy::FifoBackfill);
        assert!(f.tenant_weights().is_empty());
    }

    #[test]
    fn fair_accounting_survives_note_round_trips() {
        // note_started (restore path) must weigh exactly like a drain
        // placement, and note_finished must release it.
        let mut s = Scheduler::new(Policy::WeightedFair);
        let req = ResourceRequest::new(2, 0);
        s.note_started(0, &req);
        s.note_started(0, &req);
        let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 8, 0));
        // Tenant 0 holds 4 of 8 cores (share 0.5); tenant 1 at 0.
        alloc.try_alloc(&ResourceRequest::new(4, 0)).unwrap();
        s.push(QueuedTask {
            uid: 0,
            req: ResourceRequest::new(1, 0),
            priority: 0,
            submitted_at: 0.0,
            tenant: 0,
            est: 1.0,
        });
        s.push(QueuedTask {
            uid: 1,
            req: ResourceRequest::new(1, 0),
            priority: 0,
            submitted_at: 1.0,
            tenant: 1,
            est: 1.0,
        });
        let placed = drain(&mut s, &mut alloc);
        assert_eq!(placed[0].uid, 1, "tenant with zero usage goes first");
        // Release everything: tenant 0 back to zero share.
        s.note_finished(0, &req);
        s.note_finished(0, &req);
        s.push(QueuedTask {
            uid: 2,
            req: ResourceRequest::new(1, 0),
            priority: 0,
            submitted_at: 2.0,
            tenant: 1,
            est: 1.0,
        });
        let placed = drain(&mut s, &mut alloc);
        let uids: Vec<usize> = placed.iter().map(|p| p.uid).collect();
        assert_eq!(uids, vec![0, 2], "equal shares fall back to FIFO per pick");
    }
}
