//! Shape-bucketed ready queue: queued tasks indexed by resource shape.
//!
//! The old monolithic scheduler kept one flat vector and walked *every*
//! queued task per drain round. Under sustained saturation (thousands
//! of queued tasks, zero free resources) that round is pure waste: the
//! paper's workloads queue large homogeneous task sets, so the whole
//! walk collapses onto a handful of distinct `(cores, gpus)` shapes —
//! and within one round the allocation only shrinks, so a shape that
//! failed to place once can never place again.
//!
//! The [`ShapeQueue`] exploits that: tasks live in per-shape buckets,
//! each bucket internally sorted by the policy's [`OrdKey`], and a
//! drain round visits *bucket heads* through a k-way merge instead of
//! tasks. A bucket whose shape cannot fit the current free vector is
//! skipped wholesale, making a fully-blocked round O(shapes) instead of
//! O(queue). The merge by `OrdKey` reproduces the flat queue's policy
//! order bit-for-bit (see `tests/sched_equiv.rs`).
//!
//! ## Invariants
//!
//! - Every entry carries a monotone arrival `seq`; keys embed it, so
//!   the merge order is total and deterministic.
//! - Entries within a bucket are non-decreasing in key. Pushes with a
//!   monotone clock append in O(1); a historical out-of-order push
//!   binary-inserts instead of taxing every later drain with a sort.
//! - Between drain rounds buckets are *clean*: no taken-but-uncompacted
//!   entries. [`ShapeQueue::finish_round`] restores this after a round
//!   that removed entries; a round that placed nothing touches nothing
//!   (the no-op drain is allocation-free).
//! - Aggregate queued demand `(cores, gpus)` is maintained
//!   incrementally, so the autoscaler's backlog probe is O(1) instead
//!   of O(queue).

use std::collections::{BTreeMap, VecDeque};

use super::QueuedTask;
use crate::resources::ResourceRequest;

/// Total, policy-defined merge order over queued tasks: compared as
/// `(major, time, seq)` with `f64::total_cmp` on the time component.
/// Policies map onto it as:
///
/// - FIFO-family: `major = 0`, `time = submitted_at`;
/// - pipeline-age: `major = priority`, `time = submitted_at`;
/// - smallest-first: `major = weighted size`, `time = 0`.
///
/// The arrival `seq` makes the order total (stable tie-breaks).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrdKey {
    pub major: u64,
    pub time: f64,
    pub seq: u64,
}

impl Eq for OrdKey {}

impl Ord for OrdKey {
    fn cmp(&self, other: &OrdKey) -> std::cmp::Ordering {
        self.major
            .cmp(&other.major)
            .then(self.time.total_cmp(&other.time))
            .then(self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for OrdKey {
    fn partial_cmp(&self, other: &OrdKey) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Debug, Clone)]
struct Entry {
    key: OrdKey,
    task: QueuedTask,
    taken: bool,
}

#[derive(Debug, Clone)]
struct Bucket {
    shape: ResourceRequest,
    /// Non-decreasing in `key`; may hold taken entries mid-round.
    entries: VecDeque<Entry>,
    /// Taken-but-uncompacted entries (nonzero only mid-round).
    taken: usize,
    /// Already queued for compaction this round.
    dirty: bool,
}

impl Bucket {
    fn live(&self) -> usize {
        self.entries.len() - self.taken
    }
}

/// The bucketed ready queue (see the module docs for the invariants).
///
/// # Examples
///
/// ```
/// use asyncflow::resources::ResourceRequest;
/// use asyncflow::sched::{OrdKey, QueuedTask, ShapeQueue};
///
/// let mut q = ShapeQueue::new();
/// for uid in 0..4 {
///     let req = ResourceRequest::new(if uid % 2 == 0 { 1 } else { 8 }, 0);
///     let t = QueuedTask { uid, req, priority: 0, submitted_at: uid as f64, tenant: 0, est: 1.0 };
///     q.push(t, |t, seq| OrdKey { major: 0, time: t.submitted_at, seq });
/// }
/// assert_eq!(q.len(), 4);
/// assert_eq!(q.shape_count(), 2, "two distinct shapes, two buckets");
/// assert_eq!(q.demand(), (2 * 1 + 2 * 8, 0));
/// // Insertion order is recoverable for checkpoints.
/// let uids: Vec<usize> = q.queued().iter().map(|t| t.uid).collect();
/// assert_eq!(uids, vec![0, 1, 2, 3]);
/// ```
#[derive(Debug, Clone, Default)]
pub struct ShapeQueue {
    buckets: Vec<Bucket>,
    /// Shape → bucket id. Bucket ids are assigned in first-seen order
    /// (never from map iteration); the map is ordered anyway (BTree,
    /// not hash) so *no* traversal of it can introduce
    /// order-nondeterminism into drains or snapshots (lint DET002).
    index: BTreeMap<ResourceRequest, usize>,
    live: usize,
    next_seq: u64,
    demand_cores: u64,
    demand_gpus: u64,
    /// Buckets with taken entries awaiting [`finish_round`](Self::finish_round).
    compact: Vec<usize>,
}

impl ShapeQueue {
    pub fn new() -> ShapeQueue {
        ShapeQueue::default()
    }

    /// Live (queued, untaken) tasks across all buckets.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Total `(cores, gpus)` requested by the queued tasks — maintained
    /// incrementally, O(1).
    pub fn demand(&self) -> (u64, u64) {
        (self.demand_cores, self.demand_gpus)
    }

    /// Number of bucket slots, including currently-empty ones (bucket
    /// ids below this bound are valid for the accessors).
    pub fn bucket_slots(&self) -> usize {
        self.buckets.len()
    }

    /// Number of distinct shapes with at least one live task.
    pub fn shape_count(&self) -> usize {
        self.buckets.iter().filter(|b| b.live() > 0).count()
    }

    /// Bucket ids with at least one live task.
    pub fn bucket_ids(&self) -> impl Iterator<Item = usize> + '_ {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, b)| b.live() > 0)
            .map(|(i, _)| i)
    }

    /// The resource shape shared by every task in bucket `b`.
    pub fn shape(&self, b: usize) -> ResourceRequest {
        self.buckets[b].shape
    }

    /// Live tasks in bucket `b`.
    pub fn live_in(&self, b: usize) -> usize {
        self.buckets[b].live()
    }

    /// Physical index of the first live entry of bucket `b`.
    pub fn first_live(&self, b: usize) -> Option<usize> {
        self.buckets[b].entries.iter().position(|e| !e.taken)
    }

    /// Physical index of the next live entry after `idx` in bucket `b`.
    pub fn next_live(&self, b: usize, idx: usize) -> Option<usize> {
        self.buckets[b]
            .entries
            .iter()
            .skip(idx + 1)
            .position(|e| !e.taken)
            .map(|off| idx + 1 + off)
    }

    /// The task at a physical index (must be live).
    pub fn task_at(&self, b: usize, idx: usize) -> &QueuedTask {
        let e = &self.buckets[b].entries[idx];
        debug_assert!(!e.taken, "task_at on a taken entry");
        &e.task
    }

    /// The merge key at a physical index.
    pub fn key_at(&self, b: usize, idx: usize) -> OrdKey {
        self.buckets[b].entries[idx].key
    }

    /// Live `(physical index, task, key)` triples of bucket `b`, in key
    /// order.
    pub fn iter_live(&self, b: usize) -> impl Iterator<Item = (usize, &QueuedTask, OrdKey)> {
        self.buckets[b]
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| !e.taken)
            .map(|(i, e)| (i, &e.task, e.key))
    }

    /// Enqueue a task; `key_of` maps `(task, arrival seq)` to the
    /// policy's merge key. Appends in O(1) when keys arrive in order
    /// (the monotone-clock common case); binary-inserts otherwise.
    pub fn push(&mut self, task: QueuedTask, key_of: impl FnOnce(&QueuedTask, u64) -> OrdKey) {
        let seq = self.next_seq;
        self.next_seq += 1;
        let key = key_of(&task, seq);
        let b = match self.index.get(&task.req) {
            Some(&b) => b,
            None => {
                let b = self.buckets.len();
                self.buckets.push(Bucket {
                    shape: task.req,
                    entries: VecDeque::new(),
                    taken: 0,
                    dirty: false,
                });
                self.index.insert(task.req, b);
                b
            }
        };
        let bucket = &mut self.buckets[b];
        debug_assert_eq!(bucket.taken, 0, "push mid-round (bucket not compacted)");
        self.live += 1;
        self.demand_cores += task.req.cpu_cores as u64;
        self.demand_gpus += task.req.gpus as u64;
        let entry = Entry { key, task, taken: false };
        match bucket.entries.back() {
            Some(last) if last.key > key => {
                let pos = bucket.entries.partition_point(|e| e.key <= key);
                bucket.entries.insert(pos, entry);
            }
            _ => bucket.entries.push_back(entry),
        }
    }

    /// Remove (mark taken) the live entry at a physical index and
    /// return its task. Physical indices of *other* entries stay valid
    /// until [`finish_round`](Self::finish_round).
    pub fn take(&mut self, b: usize, idx: usize) -> QueuedTask {
        let bucket = &mut self.buckets[b];
        let e = &mut bucket.entries[idx];
        debug_assert!(!e.taken, "take on an already-taken entry");
        e.taken = true;
        let task = e.task;
        bucket.taken += 1;
        if !bucket.dirty {
            bucket.dirty = true;
            self.compact.push(b);
        }
        self.live -= 1;
        self.demand_cores -= task.req.cpu_cores as u64;
        self.demand_gpus -= task.req.gpus as u64;
        task
    }

    /// Compact every bucket touched since the last call, restoring the
    /// clean-between-rounds invariant. A round that took nothing is a
    /// no-op (no allocation, no copying).
    pub fn finish_round(&mut self) {
        while let Some(b) = self.compact.pop() {
            let bucket = &mut self.buckets[b];
            bucket.entries.retain(|e| !e.taken);
            bucket.taken = 0;
            bucket.dirty = false;
        }
    }

    /// The queued tasks in insertion (arrival `seq`) order — the
    /// checkpoint representation: re-pushing them into a fresh queue in
    /// this order reproduces every bucket and tie-break.
    pub fn queued(&self) -> Vec<QueuedTask> {
        let mut out: Vec<(u64, QueuedTask)> = Vec::with_capacity(self.live);
        for b in &self.buckets {
            for e in &b.entries {
                if !e.taken {
                    out.push((e.key.seq, e.task));
                }
            }
        }
        out.sort_by_key(|&(seq, _)| seq);
        out.into_iter().map(|(_, t)| t).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fifo_key(t: &QueuedTask, seq: u64) -> OrdKey {
        OrdKey { major: 0, time: t.submitted_at, seq }
    }

    fn qt(uid: usize, cores: u32, gpus: u32, at: f64) -> QueuedTask {
        QueuedTask {
            uid,
            req: ResourceRequest::new(cores, gpus),
            priority: 0,
            submitted_at: at,
            tenant: 0,
            est: 1.0,
        }
    }

    #[test]
    fn buckets_group_by_shape_and_track_demand() {
        let mut q = ShapeQueue::new();
        q.push(qt(0, 4, 1, 0.0), fifo_key);
        q.push(qt(1, 4, 1, 1.0), fifo_key);
        q.push(qt(2, 8, 0, 2.0), fifo_key);
        assert_eq!(q.len(), 3);
        assert_eq!(q.shape_count(), 2);
        assert_eq!(q.demand(), (16, 2));
        let b = q.bucket_ids().next().unwrap();
        assert_eq!(q.live_in(b), 2);
        assert_eq!(q.shape(b), ResourceRequest::new(4, 1));
    }

    #[test]
    fn out_of_order_push_binary_inserts() {
        let mut q = ShapeQueue::new();
        q.push(qt(0, 1, 0, 5.0), fifo_key);
        q.push(qt(1, 1, 0, 1.0), fifo_key); // earlier, pushed later
        q.push(qt(2, 1, 0, 3.0), fifo_key);
        let b = q.bucket_ids().next().unwrap();
        let order: Vec<usize> = q.iter_live(b).map(|(_, t, _)| t.uid).collect();
        assert_eq!(order, vec![1, 2, 0], "bucket holds true FIFO order");
        // Insertion order is still recoverable (checkpoints).
        let uids: Vec<usize> = q.queued().iter().map(|t| t.uid).collect();
        assert_eq!(uids, vec![0, 1, 2]);
    }

    #[test]
    fn take_and_finish_round_keep_counts_consistent() {
        let mut q = ShapeQueue::new();
        for uid in 0..4 {
            q.push(qt(uid, 2, 0, uid as f64), fifo_key);
        }
        let b = q.bucket_ids().next().unwrap();
        let head = q.first_live(b).unwrap();
        let t = q.take(b, head);
        assert_eq!(t.uid, 0);
        assert_eq!(q.len(), 3);
        assert_eq!(q.demand(), (6, 0));
        // Mid-bucket take: indices of the rest stay stable.
        let second = q.first_live(b).unwrap();
        let third = q.next_live(b, second).unwrap();
        let t = q.take(b, third);
        assert_eq!(t.uid, 2);
        assert_eq!(q.task_at(b, second).uid, 1);
        q.finish_round();
        let order: Vec<usize> = q.iter_live(b).map(|(_, t, _)| t.uid).collect();
        assert_eq!(order, vec![1, 3]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn empty_bucket_is_skipped_but_reusable() {
        let mut q = ShapeQueue::new();
        q.push(qt(0, 1, 0, 0.0), fifo_key);
        let b = q.bucket_ids().next().unwrap();
        q.take(b, 0);
        q.finish_round();
        assert_eq!(q.shape_count(), 0);
        assert_eq!(q.bucket_ids().count(), 0);
        // Same shape returns to the same bucket slot.
        q.push(qt(1, 1, 0, 1.0), fifo_key);
        assert_eq!(q.bucket_slots(), 1);
        assert_eq!(q.len(), 1);
    }
}
