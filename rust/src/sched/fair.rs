//! Weighted fair sharing across coordinator drivers, via
//! dominant-resource usage accounting (DRF).
//!
//! The coordinator multiplexes many workflow drivers over one pilot
//! agent, and plain FIFO lets one greedy member monopolize it: a
//! campaign member that submits 10³ tasks at t = 0 holds every core
//! until its queue drains, so a small workflow arriving a second later
//! waits for all of them (the ROADMAP's starvation item). The
//! [`WeightedFair`] discipline removes that failure mode: whenever
//! resources free up, the next placement goes to the *tenant* (driver
//! slot) with the lowest weighted **dominant share** — its running
//! cores and GPUs as fractions of the schedulable capacity, the larger
//! of the two, divided by its weight. Within a tenant, tasks stay FIFO.
//!
//! Accounting is exact and checkpoint-stable: the ledger tracks only
//! *running* tasks (started minus finished), so a restore rebuilds it
//! verbatim from the snapshot's in-flight set.

use std::collections::BTreeMap;

use super::policy::{DrainCtx, SchedPolicy};
use super::queue::{OrdKey, ShapeQueue};
use super::{Policy, QueuedTask, SchedStats, ScheduledTask};
use crate::resources::{Allocator, ResourceRequest};

/// Dominant-resource fair sharing with per-tenant weights (default 1).
///
/// # Examples
///
/// A greedy tenant saturates the pilot; when a core frees up with both
/// tenants queued, the idle tenant wins it:
///
/// ```
/// use asyncflow::resources::{Allocator, ClusterSpec, ResourceRequest};
/// use asyncflow::sched::{DrainCtx, Policy, QueuedTask, Scheduler};
///
/// let mut s = Scheduler::new(Policy::WeightedFair);
/// let mut alloc = Allocator::new(&ClusterSpec::uniform("t", 1, 2, 0));
/// let qt = |uid: usize, tenant: usize, at: f64| QueuedTask {
///     uid, req: ResourceRequest::new(1, 0), priority: 0,
///     submitted_at: at, tenant, est: 10.0,
/// };
/// // Tenant 0 fills the allocation and queues more work ...
/// for uid in 0..4 { s.push(qt(uid, 0, uid as f64)); }
/// let placed = s.drain_schedulable(&mut alloc, &DrainCtx::at(0.0));
/// assert_eq!(placed.len(), 2);
/// // ... then tenant 1 arrives. One core frees: despite tenant 0's
/// // earlier submissions, the share-less tenant 1 gets it.
/// s.push(qt(9, 1, 4.0));
/// alloc.release(&placed[0].placement);
/// s.note_finished(0, &ResourceRequest::new(1, 0));
/// let next = s.drain_schedulable(&mut alloc, &DrainCtx::at(10.0));
/// assert_eq!(next.len(), 1);
/// assert_eq!(next[0].uid, 9, "lowest dominant share wins the free core");
/// ```
#[derive(Debug, Clone, Default)]
pub struct WeightedFair {
    /// Per-tenant running usage `(cores, gpus)`, indexed by tenant.
    used: Vec<(u64, u64)>,
    /// Per-tenant weight; missing entries weigh 1.0.
    weights: Vec<f64>,
}

impl WeightedFair {
    pub fn new() -> WeightedFair {
        WeightedFair::default()
    }

    fn used_of(&self, tenant: usize) -> (u64, u64) {
        self.used.get(tenant).copied().unwrap_or((0, 0))
    }

    fn weight_of(&self, tenant: usize) -> f64 {
        self.weights.get(tenant).copied().unwrap_or(1.0)
    }

    /// Weighted dominant share of `(cores, gpus)` usage against the
    /// schedulable capacity.
    fn share(&self, tenant: usize, used: (u64, u64), cap: (u64, u64)) -> f64 {
        let c = used.0 as f64 / cap.0.max(1) as f64;
        let g = used.1 as f64 / cap.1.max(1) as f64;
        c.max(g) / self.weight_of(tenant).max(1e-9)
    }
}

impl SchedPolicy for WeightedFair {
    fn kind(&self) -> Policy {
        Policy::WeightedFair
    }

    fn key(&self, t: &QueuedTask, seq: u64) -> OrdKey {
        // FIFO within a tenant; tenant selection happens at drain time.
        OrdKey { major: 0, time: t.submitted_at, seq }
    }

    fn task_started(&mut self, tenant: usize, req: &ResourceRequest) {
        if self.used.len() <= tenant {
            self.used.resize(tenant + 1, (0, 0));
        }
        self.used[tenant].0 += req.cpu_cores as u64;
        self.used[tenant].1 += req.gpus as u64;
    }

    fn task_finished(&mut self, tenant: usize, req: &ResourceRequest) {
        let u = &mut self.used[tenant];
        u.0 -= req.cpu_cores as u64;
        u.1 -= req.gpus as u64;
    }

    fn set_weight(&mut self, tenant: usize, weight: f64) {
        if self.weights.len() <= tenant {
            self.weights.resize(tenant + 1, 1.0);
        }
        self.weights[tenant] = weight.max(1e-9);
    }

    fn weights(&self) -> Vec<(usize, f64)> {
        self.weights
            .iter()
            .enumerate()
            .filter(|&(_, &w)| w != 1.0)
            .map(|(t, &w)| (t, w))
            .collect()
    }

    fn drain(
        &mut self,
        q: &mut ShapeQueue,
        alloc: &mut Allocator,
        _ctx: &DrainCtx,
        stats: &mut SchedStats,
    ) -> Vec<ScheduledTask> {
        // Shape screen first: a fully-blocked round (the saturated hot
        // path) costs O(shapes) and never touches per-task state.
        let mut blocked = vec![false; q.bucket_slots()];
        let mut any_fit = false;
        for b in q.bucket_ids() {
            stats.shape_probes += 1;
            if alloc.may_fit(&q.shape(b)) {
                any_fit = true;
            } else {
                blocked[b] = true;
            }
        }
        if !any_fit {
            return Vec::new();
        }
        // Per-tenant FIFO candidate lists over the unblocked buckets.
        //
        // Collection is capped: a bucket can yield at most
        // `bound = min(free / shape)` placements this round (each
        // placement shrinks the free vector by a full shape, and
        // releases never happen mid-round), and a tenant's placements
        // from one bucket are a key-order *prefix* of its entries
        // there — so collecting only each tenant's first `bound`
        // entries per bucket is exactly equivalent to the uncapped
        // walk while bounding sort and selection cost by the round's
        // placeable work, not the queue length. The one linear pass
        // over live entries of placeable shapes remains (tenants must
        // be discovered); the fully-blocked saturated path above never
        // reaches it.
        let (free_c, free_g) = (alloc.free_cores(), alloc.free_gpus());
        let mut cands: BTreeMap<usize, (Vec<(OrdKey, usize, usize)>, usize)> = BTreeMap::new();
        let mut per_bucket: BTreeMap<usize, usize> = BTreeMap::new();
        for b in q.bucket_ids() {
            if blocked[b] {
                continue;
            }
            let shape = q.shape(b);
            let by_c = if shape.cpu_cores == 0 {
                usize::MAX
            } else {
                (free_c / shape.cpu_cores as u64).min(usize::MAX as u64) as usize
            };
            let by_g = if shape.gpus == 0 {
                usize::MAX
            } else {
                (free_g / shape.gpus as u64).min(usize::MAX as u64) as usize
            };
            // may_fit passed, so the bound is >= 1.
            let bound = by_c.min(by_g).max(1);
            per_bucket.clear();
            for (idx, task, key) in q.iter_live(b) {
                let n = per_bucket.entry(task.tenant).or_insert(0);
                if *n >= bound {
                    continue;
                }
                *n += 1;
                cands.entry(task.tenant).or_default().0.push((key, b, idx));
            }
        }
        for (list, _) in cands.values_mut() {
            list.sort_unstable();
        }
        // Round-local usage overlay: placements made this round raise
        // the tenant's share immediately (the ledger itself is updated
        // by the caller's task_started hook afterwards).
        let cap = (alloc.capacity_cores(), alloc.capacity_gpus());
        let mut local: BTreeMap<usize, (u64, u64)> = BTreeMap::new();
        let mut placed = Vec::new();
        loop {
            // Lowest weighted dominant share among tenants with
            // candidates left; ties break toward the lower tenant id.
            let mut best: Option<(f64, usize)> = None;
            for (&t, (list, pos)) in &cands {
                if *pos >= list.len() {
                    continue;
                }
                let extra = local.get(&t).copied().unwrap_or((0, 0));
                let u = self.used_of(t);
                let s = self.share(t, (u.0 + extra.0, u.1 + extra.1), cap);
                if best.is_none_or(|(bs, _)| s < bs) {
                    best = Some((s, t));
                }
            }
            let Some((_, t)) = best else { break };
            // Walk the chosen tenant's FIFO list to its next placeable
            // task; every step advances a cursor, so the whole round is
            // O(candidates). A tenant whose cursor reaches the end
            // simply stops being selectable.
            let (list, pos) = cands.get_mut(&t).expect("selected tenant has candidates");
            while *pos < list.len() {
                let (_, b, idx) = list[*pos];
                *pos += 1;
                if blocked[b] {
                    continue;
                }
                stats.tasks_examined += 1;
                let task = *q.task_at(b, idx);
                match alloc.try_alloc(&task.req) {
                    Some(placement) => {
                        q.take(b, idx);
                        let e = local.entry(t).or_default();
                        e.0 += task.req.cpu_cores as u64;
                        e.1 += task.req.gpus as u64;
                        placed.push(ScheduledTask { uid: task.uid, placement, task });
                        break;
                    }
                    None => {
                        stats.shape_probes += 1;
                        blocked[b] = true;
                    }
                }
            }
        }
        placed
    }
}
