//! Crate-wide error type.

use thiserror::Error;

/// All failure modes surfaced by asyncflow's public API.
#[derive(Error, Debug)]
pub enum Error {
    /// Dependency graph is malformed (cycle, dangling edge, ...).
    #[error("invalid DAG: {0}")]
    InvalidDag(String),

    /// A task requests more resources than the whole allocation owns.
    #[error("unsatisfiable resource request: {0}")]
    Unsatisfiable(String),

    /// Workflow construction / configuration problem.
    #[error("invalid workflow: {0}")]
    InvalidWorkflow(String),

    /// Configuration file / JSON problem.
    #[error("config error: {0}")]
    Config(String),

    /// JSON parse error with byte offset context.
    #[error("json parse error at byte {offset}: {message}")]
    Json { offset: usize, message: String },

    /// Artifact (AOT HLO) loading / execution problem.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Engine / executor invariant violation.
    #[error("engine error: {0}")]
    Engine(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// Underlying XLA / PJRT error.
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
