//! Crate-wide error type (hand-rolled `Display`/`Error` impls — no
//! `thiserror` dependency; the crate builds with zero external deps).

use std::fmt;

/// All failure modes surfaced by asyncflow's public API.
#[derive(Debug)]
pub enum Error {
    /// Dependency graph is malformed (cycle, dangling edge, ...).
    InvalidDag(String),

    /// A task requests more resources than the whole allocation owns.
    Unsatisfiable(String),

    /// Workflow construction / configuration problem.
    InvalidWorkflow(String),

    /// Configuration file / JSON problem.
    Config(String),

    /// JSON parse error with byte offset context.
    Json { offset: usize, message: String },

    /// Artifact (AOT HLO) loading / execution problem.
    Runtime(String),

    /// Engine / executor invariant violation.
    Engine(String),

    /// A task killed by failure injection ran out of retry attempts
    /// (see [`crate::failure::RetryPolicy`]). Typed so callers can
    /// tell an exhausted retry budget from a wedged run.
    RetriesExhausted {
        /// Workflow the task belongs to.
        workflow: String,
        /// Coordinator-global task uid.
        uid: usize,
        /// Attempts consumed (initial run + retries).
        attempts: u32,
    },

    Io(std::io::Error),

    /// Underlying XLA / PJRT error (`pjrt` feature).
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidDag(m) => write!(f, "invalid DAG: {m}"),
            Error::Unsatisfiable(m) => write!(f, "unsatisfiable resource request: {m}"),
            Error::InvalidWorkflow(m) => write!(f, "invalid workflow: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Json { offset, message } => {
                write!(f, "json parse error at byte {offset}: {message}")
            }
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Engine(m) => write!(f, "engine error: {m}"),
            Error::RetriesExhausted { workflow, uid, attempts } => write!(
                f,
                "retries exhausted: task uid {uid} of workflow '{workflow}' \
                 failed {attempts} times"
            ),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_keeps_wire_format() {
        assert_eq!(Error::Engine("boom".into()).to_string(), "engine error: boom");
        assert_eq!(
            Error::Json { offset: 7, message: "bad".into() }.to_string(),
            "json parse error at byte 7: bad"
        );
        let io: Error = std::io::Error::new(std::io::ErrorKind::Other, "nope").into();
        assert!(io.to_string().starts_with("io error: "));
        assert_eq!(
            Error::RetriesExhausted { workflow: "ddmd".into(), uid: 9, attempts: 4 }.to_string(),
            "retries exhausted: task uid 9 of workflow 'ddmd' failed 4 times"
        );
    }
}
