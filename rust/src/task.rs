//! Task and task-set model (substrate S6).
//!
//! The paper treats tasks as black boxes with four dimensions of
//! heterogeneity: implementation, resource requirements, duration and
//! size (§1). [`TaskSetSpec`] captures a *task set* (a node of the
//! dependency graph): `tasks` identical black boxes, each with a
//! [`ResourceRequest`] and a stochastic execution time
//! TX ~ N(mu, (sigma_frac*mu)^2), exactly as Tables 1–2 specify.

use crate::error::{Error, Result};
use crate::resources::ResourceRequest;
use crate::util::json::{obj, FromJson, Json, ToJson};
use crate::util::rng::Rng;

/// What a task actually *does* when executed by a real executor.
///
/// The virtual (discrete-event) executor ignores this; the stress
/// executor sleeps/spins; the ML executor dispatches to the PJRT
/// runtime (DeepDriveMD task bodies).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TaskKind {
    /// Synthetic task occupying resources for TX seconds (the paper's
    /// `stress` executable).
    Stress,
    /// Run MD via the `md_step` artifact and featurize frames.
    MdSimulation { chunks: usize },
    /// Aggregate contact-map frames into training batches.
    Aggregation,
    /// Run `ae_train` SGD steps on aggregated batches.
    Training { steps: usize },
    /// Score conformations with `ae_infer` (outlier detection).
    Inference,
}

impl TaskKind {
    pub fn label(&self) -> &'static str {
        match self {
            TaskKind::Stress => "stress",
            TaskKind::MdSimulation { .. } => "simulation",
            TaskKind::Aggregation => "aggregation",
            TaskKind::Training { .. } => "training",
            TaskKind::Inference => "inference",
        }
    }
}

impl ToJson for TaskKind {
    fn to_json(&self) -> Json {
        match self {
            TaskKind::MdSimulation { chunks } => obj([
                ("kind", Json::from(self.label())),
                ("chunks", Json::from(*chunks)),
            ]),
            TaskKind::Training { steps } => obj([
                ("kind", Json::from(self.label())),
                ("steps", Json::from(*steps)),
            ]),
            _ => obj([("kind", Json::from(self.label()))]),
        }
    }
}

impl FromJson for TaskKind {
    fn from_json(v: &Json) -> Result<TaskKind> {
        match v.req_str("kind")? {
            "stress" => Ok(TaskKind::Stress),
            "simulation" => Ok(TaskKind::MdSimulation {
                chunks: v.req_u64("chunks")? as usize,
            }),
            "aggregation" => Ok(TaskKind::Aggregation),
            "training" => Ok(TaskKind::Training { steps: v.req_u64("steps")? as usize }),
            "inference" => Ok(TaskKind::Inference),
            other => Err(Error::Config(format!("unknown task kind '{other}'"))),
        }
    }
}

/// A *task set*: `tasks` homogeneous tasks (one DG node, cf. Fig. 2).
#[derive(Debug, Clone)]
pub struct TaskSetSpec {
    /// Unique name, e.g. `"Sim0"` or `"T3"`.
    pub name: String,
    /// Number of tasks in the set.
    pub tasks: u32,
    /// Per-task resource requirement.
    pub req: ResourceRequest,
    /// Mean task execution time, seconds (paper scale).
    pub tx_mean: f64,
    /// Std-dev as a fraction of the mean (paper: 0.05).
    pub tx_sigma_frac: f64,
    /// Body executed by real executors.
    pub kind: TaskKind,
}

impl TaskSetSpec {
    pub fn new(
        name: impl Into<String>,
        tasks: u32,
        req: ResourceRequest,
        tx_mean: f64,
    ) -> TaskSetSpec {
        TaskSetSpec {
            name: name.into(),
            tasks,
            req,
            tx_mean,
            tx_sigma_frac: 0.05,
            kind: TaskKind::Stress,
        }
    }

    pub fn with_kind(mut self, kind: TaskKind) -> Self {
        self.kind = kind;
        self
    }

    pub fn with_sigma(mut self, frac: f64) -> Self {
        self.tx_sigma_frac = frac;
        self
    }

    /// Sample a concrete TX for one task of this set.
    pub fn sample_tx(&self, rng: &mut Rng) -> f64 {
        if self.tx_sigma_frac == 0.0 {
            self.tx_mean
        } else {
            rng.normal_pos(self.tx_mean, self.tx_sigma_frac * self.tx_mean)
        }
    }

    /// Aggregate footprint if every task of the set ran concurrently.
    pub fn full_footprint(&self) -> (u64, u64) {
        (
            self.tasks as u64 * self.req.cpu_cores as u64,
            self.tasks as u64 * self.req.gpus as u64,
        )
    }
}

impl ToJson for TaskSetSpec {
    fn to_json(&self) -> Json {
        obj([
            ("name", Json::from(self.name.clone())),
            ("tasks", Json::from(self.tasks as usize)),
            ("req", self.req.to_json()),
            ("tx_mean", Json::from(self.tx_mean)),
            ("tx_sigma_frac", Json::from(self.tx_sigma_frac)),
            ("task_kind", self.kind.to_json()),
        ])
    }
}

impl FromJson for TaskSetSpec {
    fn from_json(v: &Json) -> Result<TaskSetSpec> {
        Ok(TaskSetSpec {
            name: v.req_str("name")?.to_string(),
            tasks: v.req_u64("tasks")? as u32,
            req: ResourceRequest::from_json(v.get("req"))?,
            tx_mean: v.req_f64("tx_mean")?,
            tx_sigma_frac: v.req_f64("tx_sigma_frac")?,
            kind: TaskKind::from_json(v.get("task_kind"))?,
        })
    }
}

/// A concrete task instance produced by expanding a [`TaskSetSpec`].
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// Unique id within a run.
    pub uid: usize,
    /// Index of the owning task set (within the workflow).
    pub set_idx: usize,
    /// Index within the set (0..tasks).
    pub ordinal: u32,
    /// Sampled execution time (paper-scale seconds).
    pub tx: f64,
    pub req: ResourceRequest,
    pub kind: TaskKind,
}

impl ToJson for TaskSpec {
    fn to_json(&self) -> Json {
        obj([
            ("uid", Json::from(self.uid)),
            ("set_idx", Json::from(self.set_idx)),
            ("ordinal", Json::from(self.ordinal as usize)),
            ("tx", Json::from(self.tx)),
            ("req", self.req.to_json()),
            ("task_kind", self.kind.to_json()),
        ])
    }
}

impl FromJson for TaskSpec {
    fn from_json(v: &Json) -> Result<TaskSpec> {
        Ok(TaskSpec {
            uid: v.req_u64("uid")? as usize,
            set_idx: v.req_u64("set_idx")? as usize,
            ordinal: v.req_u64("ordinal")? as u32,
            tx: v.req_f64("tx")?,
            req: ResourceRequest::from_json(v.get("req"))?,
            kind: TaskKind::from_json(v.get("task_kind"))?,
        })
    }
}

/// Task lifecycle states, mirroring RADICAL-Pilot's task state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    /// Known to the engine, dependencies not yet satisfied.
    New,
    /// Dependencies satisfied, waiting for resources.
    Ready,
    /// Placed on resources, executing.
    Running,
    /// Finished successfully.
    Done,
    /// Failed (failure-injection tests).
    Failed,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::ResourceRequest;

    fn set() -> TaskSetSpec {
        TaskSetSpec::new("Sim0", 96, ResourceRequest::new(4, 1), 340.0)
    }

    #[test]
    fn sample_tx_respects_sigma() {
        let s = set();
        let mut rng = Rng::new(1);
        let samples: Vec<f64> = (0..5000).map(|_| s.sample_tx(&mut rng)).collect();
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!((mean - 340.0).abs() < 5.0, "mean {mean}");
        assert!(samples.iter().all(|&t| t > 0.0));
        let sd = (samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / samples.len() as f64)
            .sqrt();
        assert!((sd - 17.0).abs() < 2.0, "sd {sd}"); // 0.05 * 340
    }

    #[test]
    fn zero_sigma_is_deterministic() {
        let s = set().with_sigma(0.0);
        let mut rng = Rng::new(1);
        assert_eq!(s.sample_tx(&mut rng), 340.0);
    }

    #[test]
    fn full_footprint() {
        let s = set();
        assert_eq!(s.full_footprint(), (384, 96));
    }

    #[test]
    fn kind_labels() {
        assert_eq!(TaskKind::Stress.label(), "stress");
        assert_eq!(TaskKind::MdSimulation { chunks: 1 }.label(), "simulation");
        assert_eq!(TaskKind::Training { steps: 5 }.label(), "training");
    }
}
