//! TX-masking analysis (§5.3): which task sets' execution times are
//! hidden by longer-running concurrent sets in the asynchronous
//! realization — and therefore do not contribute to the workflow TTX.

use crate::engine::ExecutionMode;
use crate::entk::Workflow;
use crate::model::set_duration;
use crate::resources::ClusterSpec;

/// Masking verdict for one task set.
#[derive(Debug, Clone)]
pub struct SetMasking {
    pub set_name: String,
    /// Wave-aware duration on this cluster.
    pub duration: f64,
    /// Earliest start / finish on the infinite-resource critical-path
    /// schedule of the asynchronous realization.
    pub start: f64,
    pub finish: f64,
    /// True when the set lies off the critical path — its TX is masked
    /// (slack > 0).
    pub masked: bool,
    /// Slack: how much the set's duration could grow before it joins
    /// the critical path.
    pub slack: f64,
}

/// Whole-workflow masking report.
#[derive(Debug, Clone)]
pub struct MaskingReport {
    pub sets: Vec<SetMasking>,
    pub critical_path: f64,
    /// Total masked seconds: sum of durations of masked sets (the
    /// paper's "TX-masked tasks do not contribute to the overall TTX").
    pub masked_seconds: f64,
}

/// Analyze masking on the asynchronous realization (infinite-resource
/// earliest/latest schedule over the jobset graph).
pub fn masking_report(wf: &Workflow, cluster: &ClusterSpec) -> MaskingReport {
    let jobsets = crate::engine::compile(wf, ExecutionMode::Asynchronous);
    let n = jobsets.len();
    let dur: Vec<f64> = jobsets
        .iter()
        .map(|j| set_duration(&wf.sets[j.set_idx], cluster))
        .collect();

    // Forward pass: earliest finish.
    let mut children: Vec<Vec<usize>> = vec![vec![]; n];
    let mut indeg = vec![0usize; n];
    for (i, j) in jobsets.iter().enumerate() {
        indeg[i] = j.deps.len();
        for &d in &j.deps {
            children[d].push(i);
        }
    }
    let mut order: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut head = 0;
    let mut est = vec![0.0f64; n]; // earliest start
    let mut eft = vec![0.0f64; n]; // earliest finish
    while head < order.len() {
        let i = order[head];
        head += 1;
        est[i] = jobsets[i].deps.iter().map(|&d| eft[d]).fold(0.0, f64::max);
        eft[i] = est[i] + dur[i];
        for &c in &children[i] {
            indeg[c] -= 1;
            if indeg[c] == 0 {
                order.push(c);
            }
        }
    }
    let cp = eft.iter().copied().fold(0.0, f64::max);

    // Backward pass: latest finish without extending the critical path.
    let mut lft = vec![cp; n];
    for &i in order.iter().rev() {
        if !children[i].is_empty() {
            lft[i] = children[i]
                .iter()
                .map(|&c| lft[c] - dur[c])
                .fold(f64::INFINITY, f64::min);
        }
    }

    let sets = (0..n)
        .map(|i| {
            let slack = lft[i] - eft[i];
            SetMasking {
                set_name: wf.sets[jobsets[i].set_idx].name.clone(),
                duration: dur[i],
                start: est[i],
                finish: eft[i],
                masked: slack > 1e-9,
                slack,
            }
        })
        .collect::<Vec<_>>();
    let masked_seconds = sets.iter().filter(|s| s.masked).map(|s| s.duration).sum();
    MaskingReport { sets, critical_path: cp, masked_seconds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::figures;
    use crate::entk::{Pipeline, Workflow};
    use crate::resources::ResourceRequest;
    use crate::task::TaskSetSpec;

    /// §5.3's Fig. 2b example: branch H2 = {T2, T4} has TTX 5000 equal to
    /// H1 = {T1, T3, T5}; with t4=4000 masking t3+t5's tail.
    #[test]
    fn worked_example_masking() {
        let dag = figures::fig2b();
        let tx = [500.0, 1000.0, 1000.0, 2000.0, 4000.0, 2000.0];
        let sets = (0..6)
            .map(|i| {
                TaskSetSpec::new(format!("T{i}"), 1, ResourceRequest::new(1, 0), tx[i])
                    .with_sigma(0.0)
            })
            .collect();
        let wf = Workflow {
            name: "fig2b".into(),
            sets,
            dag,
            sequential: vec![Pipeline::new("s").stage(&[0]).stage(&[1, 2]).stage(&[3, 4]).stage(&[5])],
            asynchronous: vec![
                Pipeline::new("p0").stage(&[0]),
                Pipeline::new("h1").stage(&[1]).stage(&[3]).stage(&[5]),
                Pipeline::new("h2").stage(&[2]).stage(&[4]),
            ],
        };
        let cluster = crate::resources::ClusterSpec::uniform("inf", 1, 64, 0);
        let r = masking_report(&wf, &cluster);
        assert!((r.critical_path - 5500.0).abs() < 1e-9);
        // Both chains tie (equality case of Eqn. 4): nothing is slack.
        let slack_names: Vec<&str> = r
            .sets
            .iter()
            .filter(|s| s.masked)
            .map(|s| s.set_name.as_str())
            .collect();
        assert!(slack_names.is_empty(), "tie case: {slack_names:?}");

        // Shrink t4 to 3000: chain H2 now has 1000s of slack.
        let mut wf2 = wf;
        wf2.sets[4].tx_mean = 3000.0;
        let r2 = masking_report(&wf2, &cluster);
        assert!((r2.critical_path - 5500.0).abs() < 1e-9);
        let masked: Vec<&str> = r2
            .sets
            .iter()
            .filter(|s| s.masked)
            .map(|s| s.set_name.as_str())
            .collect();
        assert_eq!(masked, vec!["T2", "T4"]);
        assert!(r2.masked_seconds == 1000.0 + 3000.0);
    }
}
