//! Analytic DOA_res (§5.2): wavefront analysis of the asynchronous
//! realization.
//!
//! The paper's examples reason about DOA_res by asking, at the
//! workflow's execution frontiers, *how many independent branches can
//! have their current task set resident on the allocation at once*
//! (e.g. DDMD: a Simulation set takes all 96 GPUs, so at most one
//! branch can be in Simulation while another progresses through
//! CPU-side Aggregation — DOA_res = 1).
//!
//! The wavefront algorithm walks the asynchronous pipelines in
//! lockstep: at every step it greedily places each pipeline's current
//! stage (full concurrent footprint of all member sets) into an empty
//! allocation, in pipeline order; placed pipelines advance. DOA_res is
//! the maximum number of *distinct dependency branches* ever co-resident,
//! minus one, capped at DOA_dep (resources cannot permit more
//! asynchronicity than dependencies do).

use std::collections::BTreeSet;

use crate::dag::DagAnalysis;
use crate::entk::Workflow;
use crate::resources::{Allocator, ClusterSpec};

/// Analytic resource-permitted degree of asynchronicity.
pub fn doa_res_analytic(wf: &Workflow, cluster: &ClusterSpec) -> usize {
    let analysis = wf.analysis();
    let branch_of = &analysis.branches.branch_of;
    let pipelines = &wf.asynchronous;
    let mut stage_idx = vec![0usize; pipelines.len()];
    let mut best = 0usize;
    // Set indices whose stages were placed in *previous* steps — a
    // stage is eligible only when the DAG parents of all its members
    // are complete (cross-pipeline dependencies respected).
    let mut completed: BTreeSet<usize> = BTreeSet::new();

    // Bounded walk (progress is forced, so this terminates; the bound
    // is belt-and-braces).
    let total_stages: usize = pipelines.iter().map(|p| p.stages.len()).sum();
    for _ in 0..total_stages * 2 + 4 {
        if stage_idx
            .iter()
            .zip(pipelines)
            .all(|(&s, p)| s >= p.stages.len())
        {
            break;
        }
        let mut alloc = Allocator::new(cluster);
        let mut branches: BTreeSet<usize> = BTreeSet::new();
        let mut advanced = Vec::new();
        for (pi, p) in pipelines.iter().enumerate() {
            if stage_idx[pi] >= p.stages.len() {
                continue;
            }
            let stage = &p.stages[stage_idx[pi]];
            let eligible = stage.sets.iter().all(|&s| {
                wf.dag.parents(s).iter().all(|pa| completed.contains(pa))
            });
            if !eligible {
                continue;
            }
            // Try to place every task of every member set.
            let mut placements = Vec::new();
            let mut fits = true;
            'sets: for &s in &stage.sets {
                let set = &wf.sets[s];
                for _ in 0..set.tasks {
                    match alloc.try_alloc(&set.req) {
                        Some(pl) => placements.push(pl),
                        None => {
                            fits = false;
                            break 'sets;
                        }
                    }
                }
            }
            if fits {
                for &s in &stage.sets {
                    branches.insert(branch_of[s]);
                }
                advanced.push(pi);
            } else {
                // Roll back partial placement.
                for pl in &placements {
                    alloc.release(pl);
                }
            }
        }
        if advanced.is_empty() {
            // Force progress on the oldest unfinished, eligible pipeline
            // (a stage too big for even an empty allocation runs in
            // waves; an ineligible head means a cross-pipeline dep is
            // pending and some other pipeline advanced last step).
            if let Some(pi) = (0..pipelines.len()).find(|&pi| {
                stage_idx[pi] < pipelines[pi].stages.len()
                    && pipelines[pi].stages[stage_idx[pi]]
                        .sets
                        .iter()
                        .all(|&s| wf.dag.parents(s).iter().all(|pa| completed.contains(pa)))
            }) {
                for &s in &pipelines[pi].stages[stage_idx[pi]].sets {
                    branches.insert(branch_of[s]);
                }
                advanced.push(pi);
            }
        }
        best = best.max(branches.len());
        for pi in advanced {
            for &s in &pipelines[pi].stages[stage_idx[pi]].sets {
                completed.insert(s);
            }
            stage_idx[pi] += 1;
        }
    }
    best.saturating_sub(1).min(analysis.doa_dep)
}

/// Convenience: WLA = min(DOA_dep, DOA_res) (Eqn. 1).
pub fn wla(wf: &Workflow, cluster: &ClusterSpec) -> usize {
    DagAnalysis::of(&wf.dag)
        .doa_dep
        .min(doa_res_analytic(wf, cluster))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ddmd::{ddmd_workflow, DdmdConfig};
    use crate::workflows::{cdg1, cdg2};

    #[test]
    fn ddmd_doa_res_is_1_on_summit() {
        // Table 3: Simulation/Inference sets each need all 96 GPUs, so
        // only one branch can hold its GPU-heavy set while a second
        // makes CPU-side progress.
        let wf = ddmd_workflow(&DdmdConfig::paper());
        let c = ClusterSpec::summit_paper();
        assert_eq!(doa_res_analytic(&wf, &c), 1);
        assert_eq!(wla(&wf, &c), 1);
    }

    #[test]
    fn cdg_doa_res_is_2_on_ample_gpus() {
        // On the 128-GPU profile both {T3,T6} and {T4,T5} frontiers are
        // co-resident: three branches -> DOA_res = 2 (Table 3).
        let c = ClusterSpec::summit_8gpu();
        assert_eq!(doa_res_analytic(&cdg1(), &c), 2);
        assert_eq!(doa_res_analytic(&cdg2(), &c), 2);
    }

    #[test]
    fn cdg2_doa_res_clips_on_96_gpus() {
        // Table 2's c-DG2 rank-2 demand (96+16 GPUs) exceeds the stated
        // 96-GPU allocation: the wavefront clips to 2 branches.
        let c = ClusterSpec::summit_paper();
        assert_eq!(doa_res_analytic(&cdg2(), &c), 1);
    }

    #[test]
    fn unlimited_resources_hit_doa_dep() {
        let wf = ddmd_workflow(&DdmdConfig::paper());
        let c = ClusterSpec::uniform("huge", 64, 512, 16);
        assert_eq!(doa_res_analytic(&wf, &c), 2, "capped at DOA_dep");
    }
}
