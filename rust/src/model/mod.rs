//! The paper's analytical model (§5–§6, substrate S9): WLA (Eqn. 1),
//! sequential TTX (Eqn. 2), asynchronous TTX (Eqn. 3), relative
//! improvement I (Eqn. 5), the staggered-iteration refinement
//! (Eqns. 6–7), and TX-masking analysis.
//!
//! This module is the "constructs and tools to assess the performance
//! improvement that an asynchronous implementation would offer" that §2
//! faults other workflow systems for lacking: call [`predict`] before
//! committing to an asynchronous redesign of a workflow.

mod doa;
mod masking;

pub use doa::{doa_res_analytic, wla};
pub use masking::{masking_report, MaskingReport};

use crate::engine::ExecutionMode;
use crate::entk::Workflow;
use crate::resources::ClusterSpec;

/// Wave-aware duration of one task set on an otherwise-empty cluster:
/// `ceil(tasks / max_concurrent) * tx_mean`.
///
/// This is what turns DDMD's Inference (96 tasks, 2-per-node on the
/// 706-core profile) into 3 waves x 38 s = 114 s.
pub fn set_duration(set: &crate::task::TaskSetSpec, cluster: &ClusterSpec) -> f64 {
    let conc = cluster.max_concurrent(&set.req).max(1);
    let waves = (set.tasks as u64).div_ceil(conc);
    waves as f64 * set.tx_mean
}

/// Eqn. 2 — sequential TTX: the sum of stage durations of the
/// sequential realization, where a stage's duration is the longest of
/// its member sets' (wave-aware) durations, plus overhead constant C.
pub fn t_seq(wf: &Workflow, cluster: &ClusterSpec, c_overhead: f64) -> f64 {
    let mut total = 0.0;
    for p in &wf.sequential {
        for stage in &p.stages {
            let stage_t = stage
                .sets
                .iter()
                .map(|&s| set_duration(&wf.sets[s], cluster))
                .fold(0.0, f64::max);
            total += stage_t;
        }
    }
    total + c_overhead
}

/// Eqn. 3 — asynchronous TTX under the *infinite-resources-across-
/// branches* assumption: the critical path of the asynchronous
/// realization's jobset graph, with wave-aware set durations.
///
/// (Per §7.1 the paper notes Eqn. 3 "assumes infinite resources"; the
/// simulator is the finite-resource oracle.)
pub fn t_async_eqn3(wf: &Workflow, cluster: &ClusterSpec, c_overhead: f64) -> f64 {
    let jobsets = crate::engine::compile(wf, ExecutionMode::Asynchronous);
    longest_path(wf, cluster, &jobsets) + c_overhead
}

/// Same critical-path bound for the adaptive (task-level) realization.
pub fn t_adaptive_bound(wf: &Workflow, cluster: &ClusterSpec, c_overhead: f64) -> f64 {
    let jobsets = crate::engine::compile(wf, ExecutionMode::Adaptive);
    longest_path(wf, cluster, &jobsets) + c_overhead
}

fn longest_path(
    wf: &Workflow,
    cluster: &ClusterSpec,
    jobsets: &[crate::engine::JobSet],
) -> f64 {
    // Kahn order over jobsets.
    let n = jobsets.len();
    let mut indeg = vec![0usize; n];
    let mut children: Vec<Vec<usize>> = vec![vec![]; n];
    for (i, j) in jobsets.iter().enumerate() {
        indeg[i] = j.deps.len();
        for &d in &j.deps {
            children[d].push(i);
        }
    }
    let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut finish = vec![0.0f64; n];
    let mut head = 0;
    let mut best = 0.0f64;
    while head < queue.len() {
        let i = queue[head];
        head += 1;
        let start = jobsets[i].deps.iter().map(|&d| finish[d]).fold(0.0, f64::max);
        finish[i] = start + set_duration(&wf.sets[jobsets[i].set_idx], cluster);
        best = best.max(finish[i]);
        for &c in &children[i] {
            indeg[c] -= 1;
            if indeg[c] == 0 {
                queue.push(c);
            }
        }
    }
    best
}

/// Eqn. 6 (generalized as Eqn. 7) — staggered-iteration TTX for
/// DDMD-like workflows: `n` identical iteration chains whose stage `k`
/// durations are `t[k]`, where the bottleneck stage (index `bottleneck`,
/// Simulation for DDMD) serializes across iterations and every *earlier*
/// masked stage overlaps the next iteration's bottleneck:
///
/// `t_async = n*t_bottleneck + sum_masked(unmasked_count_k * t_k)`
///
/// For DDMD (n=3): 3*340 + 1*85 (Aggr: n-1 masked) + 2*63? — the paper's
/// Eqn. 6 form `n*t_seq - (n-1)*t_aggr - (n-2)*t_train` is implemented
/// verbatim by [`t_async_ddmd_eqn6`]; this generic form reproduces it.
pub fn t_async_staggered(n: usize, stage_t: &[f64], masked: &[usize]) -> f64 {
    assert_eq!(stage_t.len(), masked.len());
    let n = n as f64;
    stage_t
        .iter()
        .zip(masked)
        .map(|(&t, &m)| (n - m as f64).max(0.0) * t)
        .sum()
}

/// Eqn. 6 verbatim: `t_async = n*t_seq_iter - (n-1)*t_aggr - (n-2)*t_train`.
pub fn t_async_ddmd_eqn6(
    n: usize,
    t_iter: f64,
    t_aggr: f64,
    t_train: f64,
) -> f64 {
    n as f64 * t_iter - (n as f64 - 1.0) * t_aggr - (n as f64 - 2.0) * t_train
}

/// Eqn. 5 — relative improvement.
pub fn improvement(t_seq: f64, t_async: f64) -> f64 {
    1.0 - t_async / t_seq
}

/// Resource "area" lower bounds: no schedule can finish before the
/// total core-seconds (gpu-seconds) divided by the allocation's
/// capacity. This is the finite-resource correction the paper folds
/// into its DDMD analysis by hand (Sim/Infer sets serializing on the 96
/// GPUs); `predict` reports `max(Eqn 3, area bounds)`.
pub fn area_bounds(wf: &Workflow, cluster: &ClusterSpec) -> (f64, f64) {
    (
        wf.total_core_seconds() / cluster.total_cores() as f64,
        if cluster.total_gpus() == 0 {
            0.0
        } else {
            wf.total_gpu_seconds() / cluster.total_gpus() as f64
        },
    )
}

/// Overhead corrections the paper applies to predictions (§7, Table 3):
/// EnTK framework overhead ~4%, plus ~2% more when asynchronicity is
/// enabled.
#[derive(Debug, Clone, Copy)]
pub struct OverheadModel {
    pub framework_frac: f64,
    pub async_frac: f64,
}

impl Default for OverheadModel {
    fn default() -> Self {
        OverheadModel { framework_frac: 0.04, async_frac: 0.02 }
    }
}

impl OverheadModel {
    pub fn corrected_seq(&self, t: f64) -> f64 {
        t * (1.0 + self.framework_frac)
    }
    pub fn corrected_async(&self, t: f64) -> f64 {
        t * (1.0 + self.framework_frac + self.async_frac)
    }
}

/// The full prediction bundle — one row of Table 3, computed a priori.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub workflow: String,
    pub doa_dep: usize,
    /// Analytic DOA_res from wavefront analysis (§5.2; see
    /// [`doa_res_analytic`]).
    pub doa_res: usize,
    /// WLA = min(DOA_dep, DOA_res) (Eqn. 1).
    pub wla: usize,
    /// Eqn. 2 with overhead correction.
    pub t_seq: f64,
    /// Eqn. 3 with overhead correction.
    pub t_async: f64,
    /// Adaptive-mode critical-path bound.
    pub t_adaptive_bound: f64,
    /// Eqn. 5 on the corrected predictions.
    pub improvement: f64,
}

/// Predict a workflow's asynchronous benefit on a given allocation.
pub fn predict(wf: &Workflow, cluster: &ClusterSpec) -> Prediction {
    predict_with(wf, cluster, OverheadModel::default())
}

pub fn predict_with(wf: &Workflow, cluster: &ClusterSpec, oh: OverheadModel) -> Prediction {
    let analysis = wf.analysis();
    let doa_res = doa_res_analytic(wf, cluster);
    let raw_seq = t_seq(wf, cluster, 0.0);
    let (area_cpu, area_gpu) = area_bounds(wf, cluster);
    let raw_async = t_async_eqn3(wf, cluster, 0.0).max(area_cpu).max(area_gpu);
    let t_s = oh.corrected_seq(raw_seq);
    let t_a = oh.corrected_async(raw_async);
    Prediction {
        workflow: wf.name.clone(),
        doa_dep: analysis.doa_dep,
        doa_res,
        wla: analysis.doa_dep.min(doa_res),
        t_seq: t_s,
        t_async: t_a,
        t_adaptive_bound: oh.corrected_async(t_adaptive_bound(wf, cluster, 0.0)),
        improvement: improvement(t_s, t_a),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::figures;
    use crate::engine::{simulate_cfg, EngineConfig};
    use crate::entk::{Pipeline, Workflow};
    use crate::resources::{ClusterSpec, ResourceRequest};
    use crate::task::TaskSetSpec;

    /// §5.3 worked example on Fig. 2b: t0=500, t1=t2=1000, t3=t5=2000,
    /// t4=4000 -> tSeq=7500, tAsync=5500, I~26%. (Experiment E8.)
    fn fig2b_workflow() -> Workflow {
        let dag = figures::fig2b();
        let tx = [500.0, 1000.0, 1000.0, 2000.0, 4000.0, 2000.0];
        let sets = (0..6)
            .map(|i| {
                TaskSetSpec::new(format!("T{i}"), 1, ResourceRequest::new(1, 0), tx[i])
                    .with_sigma(0.0)
            })
            .collect();
        Workflow {
            name: "fig2b".into(),
            sets,
            dag,
            sequential: vec![Pipeline::new("seq")
                .stage(&[0])
                .stage(&[1, 2])
                .stage(&[3, 4])
                .stage(&[5])],
            asynchronous: vec![
                Pipeline::new("p0").stage(&[0]),
                Pipeline::new("h1").stage(&[1]).stage(&[3]).stage(&[5]),
                Pipeline::new("h2").stage(&[2]).stage(&[4]),
            ],
        }
    }

    fn big_cluster() -> ClusterSpec {
        ClusterSpec::uniform("inf", 4, 64, 0)
    }

    #[test]
    fn worked_example_eqn2() {
        let wf = fig2b_workflow();
        let t = t_seq(&wf, &big_cluster(), 0.0);
        assert!((t - 7500.0).abs() < 1e-9, "tSeq={t}");
    }

    #[test]
    fn worked_example_eqn3() {
        let wf = fig2b_workflow();
        let t = t_async_eqn3(&wf, &big_cluster(), 0.0);
        assert!((t - 5500.0).abs() < 1e-9, "tAsync={t}");
    }

    #[test]
    fn worked_example_improvement() {
        let i = improvement(7500.0, 5500.0);
        assert!((i - 0.2666).abs() < 1e-3, "I={i}");
    }

    #[test]
    fn simulator_agrees_with_model_on_worked_example() {
        // The discrete-event engine must land exactly on the closed form
        // when overheads are zero and resources ample.
        let wf = fig2b_workflow();
        let cfg = EngineConfig::ideal();
        let seq = simulate_cfg(&wf, &big_cluster(), ExecutionMode::Sequential, &cfg);
        let asy = simulate_cfg(&wf, &big_cluster(), ExecutionMode::Asynchronous, &cfg);
        assert!((seq.makespan - 7500.0).abs() < 1e-6, "{}", seq.makespan);
        assert!((asy.makespan - 5500.0).abs() < 1e-6, "{}", asy.makespan);
    }

    #[test]
    fn eqn6_ddmd_numbers() {
        // §7.1: n=3, t_iter=526, t_aggr=85, t_train=63 -> 1345.
        let t = t_async_ddmd_eqn6(3, 526.0, 85.0, 63.0);
        assert!((t - 1345.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn staggered_generalizes_eqn6() {
        // DDMD as stage times [340, 85, 63, 38] with masked counts
        // [0, n-1, n-2, 0]:
        let t = t_async_staggered(3, &[340.0, 85.0, 63.0, 38.0], &[0, 2, 1, 0]);
        assert!((t - 1345.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn set_duration_waves() {
        // 96 tasks, 2-per-node feasible on 16 nodes -> 32 concurrent ->
        // 3 waves.
        let set = TaskSetSpec::new("Inf", 96, ResourceRequest::new(16, 1), 38.0);
        let c = ClusterSpec::summit_706();
        assert!((set_duration(&set, &c) - 114.0).abs() < 1e-9);
        // On the SMT profile one wave suffices.
        let c2 = ClusterSpec::summit_paper();
        assert!((set_duration(&set, &c2) - 38.0).abs() < 1e-9);
    }

    #[test]
    fn overhead_model_corrections() {
        let oh = OverheadModel::default();
        assert!((oh.corrected_seq(1000.0) - 1040.0).abs() < 1e-9);
        assert!((oh.corrected_async(1000.0) - 1060.0).abs() < 1e-9);
    }

    #[test]
    fn predict_bundles_doa_and_wla() {
        let wf = fig2b_workflow();
        let p = predict(&wf, &big_cluster());
        assert_eq!(p.doa_dep, 1);
        assert_eq!(p.doa_res, 1);
        assert_eq!(p.wla, 1);
        assert!(p.improvement > 0.2 && p.improvement < 0.3, "I={}", p.improvement);
        // Adaptive can only be <= async critical path.
        assert!(p.t_adaptive_bound <= p.t_async + 1e-9);
    }
}
