//! Incremental NDJSON tailing: parse an event stream as it is written.
//!
//! `asyncflow trace` reads a finished file in one shot; the live
//! console (`asyncflow watch`) instead follows a file that a running
//! `--emit-events` simulation is still appending to. That changes the
//! parsing contract in two ways:
//!
//! - the last line is routinely **incomplete** (the writer is mid-line
//!   or mid-buffer-flush), so the parser must hold partial bytes back
//!   instead of erroring, and resume cleanly when the rest arrives;
//! - a follower must be **resumable**: [`TailParser::offset`] reports
//!   how many bytes were fully consumed (complete lines only), so a
//!   restarted watcher can seek straight past everything it already
//!   processed and re-feed from there ([`TailParser::resume_at`]).
//!
//! [`TailParser`] is the pure byte-stream half (no I/O, fully
//! deterministic — it is what the rollup property tests drive);
//! [`TailFollower`] wraps it around a [`File`] with a read-to-EOF
//! poll, still without touching the wall clock: *when* to poll again
//! is the caller's business (`obs::watch` owns the sleep).

use std::fs::File;
use std::io::{Read as _, Seek, SeekFrom};
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::{FromJson, Json};

use super::ObsEvent;

/// Read chunk size for [`TailFollower::poll`].
const CHUNK: usize = 64 * 1024;

/// Incremental NDJSON parser tolerating a partial trailing line.
///
/// Feed byte chunks in arrival order; every *complete* line (terminated
/// by `\n`) is parsed immediately, bytes after the last newline wait in
/// an internal buffer for the next [`feed`](Self::feed). Blank lines
/// are skipped but still advance the offset, exactly like
/// [`parse_stream`](super::trace::parse_stream) skips them — a one-shot
/// parse and any chunking of the same bytes produce the same events.
#[derive(Debug, Default)]
pub struct TailParser {
    /// Bytes after the last seen newline (a partial line).
    pending: Vec<u8>,
    /// Bytes fully consumed (complete lines only).
    offset: u64,
    /// Complete lines consumed, for 1-based error positions.
    lines: u64,
}

impl TailParser {
    /// Parser positioned at the start of a stream.
    pub fn new() -> TailParser {
        TailParser::default()
    }

    /// Parser resuming at a byte offset previously reported by
    /// [`offset`](Self::offset) — the caller seeks the source there
    /// and feeds from that point. Line numbers in errors restart at 1
    /// (the resumed parser has not seen the earlier lines).
    pub fn resume_at(offset: u64) -> TailParser {
        TailParser { pending: Vec::new(), offset, lines: 0 }
    }

    /// Bytes fully consumed so far: feeding a fresh source from this
    /// offset replays nothing and loses nothing. The partial trailing
    /// line (if any) is *not* counted — it will be re-read whole.
    pub fn offset(&self) -> u64 {
        self.offset
    }

    /// Bytes currently held back as a partial trailing line.
    pub fn pending_bytes(&self) -> usize {
        self.pending.len()
    }

    /// Consume a chunk, appending every event on a now-complete line to
    /// `out`. On a malformed line the error carries its 1-based line
    /// number; the parser state is unspecified afterwards (a malformed
    /// *complete* line is corruption, not a mid-write tail).
    pub fn feed(&mut self, chunk: &[u8], out: &mut Vec<ObsEvent>) -> Result<()> {
        self.pending.extend_from_slice(chunk);
        let Some(last_nl) = self.pending.iter().rposition(|&b| b == b'\n') else {
            return Ok(());
        };
        let consumed = last_nl + 1;
        for raw in self.pending[..last_nl].split(|&b| b == b'\n') {
            self.lines += 1;
            parse_line(raw, self.lines, out)?;
        }
        self.offset += consumed as u64;
        self.pending.drain(..consumed);
        Ok(())
    }

    /// End-of-stream: parse a non-empty unterminated trailing line (a
    /// file whose final line lacks `\n` — `parse_stream` accepts those
    /// too). Errors leave the bytes pending, so a live follower can
    /// treat the failure as "still mid-write" and keep feeding.
    pub fn finish(&mut self, out: &mut Vec<ObsEvent>) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let raw = std::mem::take(&mut self.pending);
        let before = out.len();
        if let Err(e) = parse_line(&raw, self.lines + 1, out) {
            self.pending = raw;
            return Err(e);
        }
        if out.len() > before || is_blank(&raw) {
            self.lines += 1;
            self.offset += raw.len() as u64;
        }
        Ok(())
    }
}

fn is_blank(raw: &[u8]) -> bool {
    raw.iter().all(|b| b.is_ascii_whitespace())
}

/// Parse one raw line (blank lines skip), pushing the event to `out`.
fn parse_line(raw: &[u8], lineno: u64, out: &mut Vec<ObsEvent>) -> Result<()> {
    let line = std::str::from_utf8(raw)
        .map_err(|e| Error::Config(format!("events line {lineno}: not UTF-8 ({e})")))?
        .trim();
    if line.is_empty() {
        return Ok(());
    }
    let v = Json::parse(line)
        .map_err(|e| Error::Config(format!("events line {lineno}: {e}")))?;
    out.push(
        ObsEvent::from_json(&v)
            .map_err(|e| Error::Config(format!("events line {lineno}: {e}")))?,
    );
    Ok(())
}

/// A [`TailParser`] attached to a file: each [`poll`](Self::poll)
/// reads whatever the writer appended since the last one and parses
/// it. Owns no clock and never sleeps — callers decide the cadence.
#[derive(Debug)]
pub struct TailFollower {
    file: File,
    parser: TailParser,
    buf: Vec<u8>,
}

impl TailFollower {
    /// Follow `path` from the beginning.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<TailFollower> {
        Self::resume(path, 0)
    }

    /// Follow `path` from a byte offset previously reported by
    /// [`offset`](Self::offset) (a restartable watch).
    pub fn resume<P: AsRef<Path>>(path: P, offset: u64) -> Result<TailFollower> {
        let mut file = File::open(path)?;
        file.seek(SeekFrom::Start(offset))?;
        Ok(TailFollower {
            file,
            parser: TailParser::resume_at(offset),
            buf: vec![0u8; CHUNK],
        })
    }

    /// Read to the file's current end, appending parsed events to
    /// `out`; returns how many were appended. A partial trailing line
    /// stays buffered for the next poll.
    pub fn poll(&mut self, out: &mut Vec<ObsEvent>) -> Result<usize> {
        let before = out.len();
        loop {
            let n = self.file.read(&mut self.buf)?;
            if n == 0 {
                break;
            }
            self.parser.feed(&self.buf[..n], out)?;
        }
        Ok(out.len() - before)
    }

    /// Bytes fully consumed (see [`TailParser::offset`]).
    pub fn offset(&self) -> u64 {
        self.parser.offset()
    }

    /// Bytes held back as a partial trailing line.
    pub fn pending_bytes(&self) -> usize {
        self.parser.pending_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::parse_stream;

    fn sample_text() -> String {
        let evs = vec![
            ObsEvent::CapacityOffered { t: 0.0, cores: 8, gpus: 2 },
            ObsEvent::WorkflowArrived {
                t: 0.0,
                slot: 0,
                workflow: "w".into(),
                arrival: 0.0,
            },
            ObsEvent::CheckpointTaken { t: 5.0 },
            ObsEvent::WorkflowCompleted { t: 9.0, slot: 0, workflow: "w".into() },
        ];
        evs.iter().map(|e| e.to_ndjson() + "\n").collect()
    }

    #[test]
    fn every_chunking_matches_the_one_shot_parse() {
        let text = sample_text();
        let want = parse_stream(&text).unwrap();
        for chunk in 1..=text.len() {
            let mut p = TailParser::new();
            let mut got = Vec::new();
            for piece in text.as_bytes().chunks(chunk) {
                p.feed(piece, &mut got).unwrap();
            }
            p.finish(&mut got).unwrap();
            assert_eq!(got, want, "chunk size {chunk}");
            assert_eq!(p.offset(), text.len() as u64, "chunk size {chunk}");
            assert_eq!(p.pending_bytes(), 0);
        }
    }

    #[test]
    fn partial_trailing_line_waits_for_the_rest() {
        let text = sample_text();
        let cut = text.len() - 10; // mid-final-line
        let mut p = TailParser::new();
        let mut got = Vec::new();
        p.feed(&text.as_bytes()[..cut], &mut got).unwrap();
        assert_eq!(got.len(), 3, "three complete lines");
        assert!(p.pending_bytes() > 0);
        let offset_mid = p.offset();
        assert!(offset_mid < cut as u64, "partial line not counted consumed");
        p.feed(&text.as_bytes()[cut..], &mut got).unwrap();
        p.finish(&mut got).unwrap();
        assert_eq!(got, parse_stream(&text).unwrap());
    }

    #[test]
    fn unterminated_final_line_parses_at_finish() {
        let text = sample_text();
        let trimmed = text.trim_end_matches('\n');
        let mut p = TailParser::new();
        let mut got = Vec::new();
        p.feed(trimmed.as_bytes(), &mut got).unwrap();
        assert_eq!(got.len(), 3);
        p.finish(&mut got).unwrap();
        assert_eq!(got, parse_stream(&text).unwrap());
        assert_eq!(p.offset(), trimmed.len() as u64);
    }

    #[test]
    fn truncated_garbage_tail_errors_but_stays_pending() {
        let mut text = sample_text();
        text.push_str("{\"ev\":\"capacity\",\"t\":1"); // mid-write tail
        let mut p = TailParser::new();
        let mut got = Vec::new();
        p.feed(text.as_bytes(), &mut got).unwrap();
        assert_eq!(got.len(), 4, "complete lines all parsed");
        let err = p.finish(&mut got).unwrap_err();
        assert!(err.to_string().contains("line 5"), "{err}");
        // The bytes stay pending: feeding the rest completes the line.
        assert!(p.pending_bytes() > 0);
        p.feed(b",\"cores\":1,\"gpus\":0}\n", &mut got).unwrap();
        assert_eq!(got.len(), 5);
    }

    #[test]
    fn resume_from_offset_replays_nothing_and_loses_nothing() {
        let text = sample_text();
        let cut = text.len() / 2;
        let mut first = TailParser::new();
        let mut got = Vec::new();
        first.feed(&text.as_bytes()[..cut], &mut got).unwrap();
        let off = first.offset() as usize;

        // A fresh parser seeks to `off` and reads from there.
        let mut second = TailParser::resume_at(off as u64);
        second.feed(&text.as_bytes()[off..], &mut got).unwrap();
        second.finish(&mut got).unwrap();
        assert_eq!(got, parse_stream(&text).unwrap());
        assert_eq!(second.offset(), text.len() as u64);
    }

    #[test]
    fn blank_lines_skip_but_advance_the_offset() {
        let text = format!("\n  \n{}", sample_text());
        let mut p = TailParser::new();
        let mut got = Vec::new();
        p.feed(text.as_bytes(), &mut got).unwrap();
        p.finish(&mut got).unwrap();
        assert_eq!(got, parse_stream(&sample_text()).unwrap());
        assert_eq!(p.offset(), text.len() as u64);
    }

    #[test]
    fn malformed_complete_line_reports_its_line_number() {
        let text = format!("{}not json\n", sample_text());
        let mut p = TailParser::new();
        let mut got = Vec::new();
        let err = p.feed(text.as_bytes(), &mut got).unwrap_err();
        assert!(err.to_string().contains("line 5"), "{err}");
    }

    #[test]
    fn follower_tails_a_growing_file() {
        let path = std::env::temp_dir().join("asyncflow_tail_follower_test.ndjson");
        let text = sample_text();
        let cut = text.len() - 7;
        std::fs::write(&path, &text.as_bytes()[..cut]).unwrap();

        let mut f = TailFollower::open(&path).unwrap();
        let mut got = Vec::new();
        f.poll(&mut got).unwrap();
        assert_eq!(got.len(), 3, "partial tail held back");

        // The writer appends the rest; the next poll completes it.
        std::fs::write(&path, text.as_bytes()).unwrap();
        let mut f2 = TailFollower::resume(&path, f.offset()).unwrap();
        f2.poll(&mut got).unwrap();
        assert_eq!(got, parse_stream(&text).unwrap());
        assert_eq!(f2.offset(), text.len() as u64);
        let _ = std::fs::remove_file(&path);
    }
}
