//! Sliding-window rollups over the event stream (the `watch` model).
//!
//! [`WindowStats`] consumes [`ObsEvent`]s in stream order and maintains
//! two views at once:
//!
//! - **instantaneous state**: queued / running / in-backoff task
//!   counts, cores and GPUs in use vs offered, per-kind concurrency
//!   with peaks — the numbers a live operator wants *right now*;
//! - **windowed rollups**: ring buffers of event timestamps inside the
//!   trailing window `(now − w, now]`, yielding arrival / start /
//!   completion / fault rates and windowed wait / TTX percentiles.
//!
//! ## Determinism contract
//!
//! Everything is keyed on **simulation time** — `now` is the latest
//! event time seen, never the wall clock, and eviction uses the exact
//! comparison `t <= now − w` on unrounded `f64`s. Feeding the same
//! stream therefore produces the same rollups whether it arrives in
//! one shot, byte-by-byte through a [`TailParser`](super::tail), or
//! across a watch session's polls — and two wake policies that emit
//! byte-identical streams roll up identically. The property test in
//! `tests/obs_watch.rs` recomputes every figure from scratch over the
//! raw prefix and asserts equality at each step, across seeds ×
//! `WakePolicy`.

use std::collections::{BTreeMap, VecDeque};

use crate::util::stats::Summary;

use super::ObsEvent;

/// Cumulative per-lane event totals since the start of the stream.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LaneTotals {
    /// Workflows materialized.
    pub arrivals: u64,
    /// Workflows completed.
    pub workflows_completed: u64,
    /// First-attempt task submissions.
    pub submissions: u64,
    /// Retry resubmissions (`attempt > 0`).
    pub resubmissions: u64,
    /// Task launches.
    pub starts: u64,
    /// Task completions.
    pub completions: u64,
    /// Node faults.
    pub faults: u64,
    /// Tasks killed by faults.
    pub kills: u64,
    /// Retries scheduled into backoff.
    pub retries_scheduled: u64,
    /// Retry budgets exhausted.
    pub retries_exhausted: u64,
    /// Timed plan resizes applied.
    pub resizes: u64,
    /// Autoscaler evaluations.
    pub autoscale_evals: u64,
    /// Autoscaler evaluations that changed the allocation.
    pub autoscale_acts: u64,
    /// Checkpoint seam markers.
    pub checkpoints: u64,
}

/// Event counts inside the trailing window, one per rate lane.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LaneWindow {
    /// Workflow arrivals in-window.
    pub arrivals: u64,
    /// Task submissions (all attempts) in-window.
    pub submissions: u64,
    /// Task launches in-window.
    pub starts: u64,
    /// Task completions in-window.
    pub completions: u64,
    /// Node faults in-window.
    pub faults: u64,
    /// Task kills in-window.
    pub kills: u64,
    /// Retries scheduled in-window.
    pub retries: u64,
}

/// One row of the per-kind concurrency table.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KindRow {
    /// Kind label.
    pub kind: String,
    /// Tasks of this kind running now.
    pub running: u64,
    /// Peak concurrent tasks of this kind.
    pub peak: u64,
    /// Completions of this kind since stream start.
    pub completed: u64,
}

/// A task the stream has submitted but not retired.
#[derive(Debug, Clone)]
struct OpenTask {
    kind: usize,
    cores: u64,
    gpus: u64,
    running: bool,
}

#[derive(Debug, Clone)]
struct SlotState {
    arrival: f64,
    first_start: Option<f64>,
}

#[derive(Debug, Clone, Default)]
struct KindLane {
    running: u64,
    peak: u64,
    completed: u64,
}

/// Sliding-window rollup engine. See the module docs for the contract.
#[derive(Debug)]
pub struct WindowStats {
    window: f64,
    now: f64,
    t0: Option<f64>,
    n_events: u64,
    cum: LaneTotals,

    // Instantaneous state.
    queued: u64,
    running: u64,
    backoff: u64,
    peak_queued: u64,
    peak_running: u64,
    used_cores: u64,
    used_gpus: u64,
    offered: (u64, u64),
    meta: Option<(f64, bool)>,

    open: BTreeMap<usize, OpenTask>,
    slots: BTreeMap<usize, SlotState>,
    kind_ids: BTreeMap<String, usize>,
    kinds: Vec<KindLane>,

    // Windowed rings: event timestamps per rate lane.
    q_arrivals: VecDeque<f64>,
    q_submissions: VecDeque<f64>,
    q_starts: VecDeque<f64>,
    q_completions: VecDeque<f64>,
    q_faults: VecDeque<f64>,
    q_kills: VecDeque<f64>,
    q_retries: VecDeque<f64>,
    // Windowed samples: (t, value).
    q_waits: VecDeque<(f64, f64)>,
    q_ttxs: VecDeque<(f64, f64)>,

    // Step histories for sparklines: (t, value); the point at or
    // before the window start is retained as the step baseline.
    h_backlog: VecDeque<(f64, f64)>,
    h_util: VecDeque<(f64, f64)>,
}

impl WindowStats {
    /// Rollups over a trailing window of `window` sim-seconds
    /// (non-positive or non-finite values mean "everything").
    pub fn new(window: f64) -> WindowStats {
        let window = if window.is_finite() && window > 0.0 {
            window
        } else {
            f64::INFINITY
        };
        WindowStats {
            window,
            now: 0.0,
            t0: None,
            n_events: 0,
            cum: LaneTotals::default(),
            queued: 0,
            running: 0,
            backoff: 0,
            peak_queued: 0,
            peak_running: 0,
            used_cores: 0,
            used_gpus: 0,
            offered: (0, 0),
            meta: None,
            open: BTreeMap::new(),
            slots: BTreeMap::new(),
            kind_ids: BTreeMap::new(),
            kinds: Vec::new(),
            q_arrivals: VecDeque::new(),
            q_submissions: VecDeque::new(),
            q_starts: VecDeque::new(),
            q_completions: VecDeque::new(),
            q_faults: VecDeque::new(),
            q_kills: VecDeque::new(),
            q_retries: VecDeque::new(),
            q_waits: VecDeque::new(),
            q_ttxs: VecDeque::new(),
            h_backlog: VecDeque::new(),
            h_util: VecDeque::new(),
        }
    }

    /// Consume one event (must arrive in stream order).
    pub fn push(&mut self, ev: &ObsEvent) {
        let t = ev.time();
        if self.t0.is_none() {
            self.t0 = Some(t);
        }
        if t > self.now {
            self.now = t;
        }
        self.n_events += 1;
        match ev {
            ObsEvent::TrafficMeta { window, failure, .. } => {
                self.meta = Some((*window, *failure));
            }
            ObsEvent::CapacityOffered { cores, gpus, .. } => {
                self.offered = (*cores, *gpus);
                self.note_util(t);
            }
            ObsEvent::WorkflowArrived { slot, arrival, .. } => {
                self.cum.arrivals += 1;
                self.q_arrivals.push_back(t);
                self.slots
                    .insert(*slot, SlotState { arrival: *arrival, first_start: None });
            }
            ObsEvent::TaskSubmitted { uid, kind, cores, gpus, attempt, .. } => {
                if *attempt > 0 {
                    self.cum.resubmissions += 1;
                    self.backoff = self.backoff.saturating_sub(1);
                } else {
                    self.cum.submissions += 1;
                }
                self.q_submissions.push_back(t);
                self.queued += 1;
                self.peak_queued = self.peak_queued.max(self.queued);
                self.note_backlog(t);
                let kind = self.kind_id(kind);
                self.open
                    .insert(*uid, OpenTask { kind, cores: *cores, gpus: *gpus, running: false });
            }
            ObsEvent::TaskStarted { uid, slot, cores, gpus, .. } => {
                self.cum.starts += 1;
                self.q_starts.push_back(t);
                self.queued = self.queued.saturating_sub(1);
                self.running += 1;
                self.peak_running = self.peak_running.max(self.running);
                self.used_cores += cores;
                self.used_gpus += gpus;
                if let Some(task) = self.open.get_mut(uid) {
                    task.running = true;
                    let k = task.kind;
                    if let Some(lane) = self.kinds.get_mut(k) {
                        lane.running += 1;
                        lane.peak = lane.peak.max(lane.running);
                    }
                }
                if let Some(s) = self.slots.get_mut(slot) {
                    if s.first_start.is_none() {
                        s.first_start = Some(t);
                        self.q_waits.push_back((t, t - s.arrival));
                    }
                }
                self.note_backlog(t);
                self.note_util(t);
            }
            ObsEvent::TaskCompleted { uid, .. } => {
                self.cum.completions += 1;
                self.q_completions.push_back(t);
                self.running = self.running.saturating_sub(1);
                self.retire(*uid, true);
                self.note_util(t);
            }
            ObsEvent::WorkflowCompleted { slot, .. } => {
                self.cum.workflows_completed += 1;
                if let Some(s) = self.slots.get(slot) {
                    self.q_ttxs.push_back((t, t - s.arrival));
                }
            }
            ObsEvent::NodeFault { .. } => {
                self.cum.faults += 1;
                self.q_faults.push_back(t);
            }
            ObsEvent::TaskKilled { uid, .. } => {
                self.cum.kills += 1;
                self.q_kills.push_back(t);
                self.running = self.running.saturating_sub(1);
                self.release(*uid);
                self.note_util(t);
            }
            ObsEvent::RetryScheduled { .. } => {
                self.cum.retries_scheduled += 1;
                self.q_retries.push_back(t);
                self.backoff += 1;
            }
            ObsEvent::RetriesExhausted { uid, .. } => {
                self.cum.retries_exhausted += 1;
                self.open.remove(uid);
            }
            ObsEvent::PilotResized { .. } => self.cum.resizes += 1,
            ObsEvent::AutoscaleDecision { acted, .. } => {
                self.cum.autoscale_evals += 1;
                if *acted {
                    self.cum.autoscale_acts += 1;
                }
            }
            ObsEvent::CheckpointTaken { .. } => self.cum.checkpoints += 1,
        }
        self.evict();
    }

    /// Free a running task's resources and per-kind slot (kill path:
    /// the entry stays open, awaiting its retry resubmission).
    fn release(&mut self, uid: usize) {
        if let Some(task) = self.open.get_mut(&uid) {
            if task.running {
                task.running = false;
                self.used_cores = self.used_cores.saturating_sub(task.cores);
                self.used_gpus = self.used_gpus.saturating_sub(task.gpus);
                let k = task.kind;
                if let Some(lane) = self.kinds.get_mut(k) {
                    lane.running = lane.running.saturating_sub(1);
                }
            }
        }
    }

    /// Retire a task for good (completion path).
    fn retire(&mut self, uid: usize, completed: bool) {
        self.release(uid);
        if let Some(task) = self.open.remove(&uid) {
            if completed {
                if let Some(lane) = self.kinds.get_mut(task.kind) {
                    lane.completed += 1;
                }
            }
        }
    }

    fn kind_id(&mut self, name: &str) -> usize {
        if let Some(&k) = self.kind_ids.get(name) {
            return k;
        }
        let k = self.kinds.len();
        self.kind_ids.insert(name.to_string(), k);
        self.kinds.push(KindLane::default());
        k
    }

    fn note_backlog(&mut self, t: f64) {
        push_step(&mut self.h_backlog, t, self.queued as f64);
    }

    fn note_util(&mut self, t: f64) {
        let frac = if self.offered.0 > 0 {
            self.used_cores as f64 / self.offered.0 as f64
        } else {
            0.0
        };
        push_step(&mut self.h_util, t, frac);
    }

    /// Evict everything outside the half-open window `(now − w, now]`.
    fn evict(&mut self) {
        if !self.window.is_finite() {
            return;
        }
        let cut = self.now - self.window;
        for q in [
            &mut self.q_arrivals,
            &mut self.q_submissions,
            &mut self.q_starts,
            &mut self.q_completions,
            &mut self.q_faults,
            &mut self.q_kills,
            &mut self.q_retries,
        ] {
            while q.front().is_some_and(|&t| t <= cut) {
                q.pop_front();
            }
        }
        for q in [&mut self.q_waits, &mut self.q_ttxs] {
            while q.front().is_some_and(|&(t, _)| t <= cut) {
                q.pop_front();
            }
        }
        // Histories keep one point at or before the cut as the step
        // baseline for sampling.
        for h in [&mut self.h_backlog, &mut self.h_util] {
            while h.len() >= 2 && h.get(1).is_some_and(|&(t, _)| t <= cut) {
                h.pop_front();
            }
        }
    }

    /// Latest event time (the dashboard's sim clock).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Configured window length (sim-seconds; ∞ = everything).
    pub fn window(&self) -> f64 {
        self.window
    }

    /// Events consumed.
    pub fn n_events(&self) -> u64 {
        self.n_events
    }

    /// Cumulative lane totals.
    pub fn totals(&self) -> &LaneTotals {
        &self.cum
    }

    /// The stream's [`ObsEvent::TrafficMeta`] header, if seen:
    /// `(arrival_window, failure_configured)`.
    pub fn meta(&self) -> Option<(f64, bool)> {
        self.meta
    }

    /// Tasks submitted and not yet started.
    pub fn queued(&self) -> u64 {
        self.queued
    }

    /// Tasks running now.
    pub fn running(&self) -> u64 {
        self.running
    }

    /// Tasks parked in retry backoff.
    pub fn backoff(&self) -> u64 {
        self.backoff
    }

    /// High-water marks of `(queued, running)`.
    pub fn peaks(&self) -> (u64, u64) {
        (self.peak_queued, self.peak_running)
    }

    /// `(cores, gpus)` in use now.
    pub fn used(&self) -> (u64, u64) {
        (self.used_cores, self.used_gpus)
    }

    /// `(cores, gpus)` offered now.
    pub fn offered(&self) -> (u64, u64) {
        self.offered
    }

    /// The span rates are computed over: the window, clipped to the
    /// stream's actual extent (a 300 s window over 40 s of events
    /// averages over 40 s, not 300).
    pub fn effective_window(&self) -> f64 {
        let span = match self.t0 {
            Some(t0) => self.now - t0,
            None => 0.0,
        };
        if span > 0.0 {
            self.window.min(span)
        } else {
            self.window
        }
    }

    /// Event counts inside the window.
    pub fn in_window(&self) -> LaneWindow {
        LaneWindow {
            arrivals: self.q_arrivals.len() as u64,
            submissions: self.q_submissions.len() as u64,
            starts: self.q_starts.len() as u64,
            completions: self.q_completions.len() as u64,
            faults: self.q_faults.len() as u64,
            kills: self.q_kills.len() as u64,
            retries: self.q_retries.len() as u64,
        }
    }

    /// In-window count → events per sim-second.
    pub fn rate(&self, count: u64) -> f64 {
        let w = self.effective_window();
        if w.is_finite() && w > 0.0 {
            count as f64 / w
        } else {
            0.0
        }
    }

    /// Windowed wait distribution (first start − arrival, sampled at
    /// the start instant).
    pub fn wait(&self) -> Option<Summary> {
        let xs: Vec<f64> = self.q_waits.iter().map(|&(_, v)| v).collect();
        Summary::try_of(&xs)
    }

    /// Windowed TTX distribution (sampled at workflow completion).
    pub fn ttx(&self) -> Option<Summary> {
        let xs: Vec<f64> = self.q_ttxs.iter().map(|&(_, v)| v).collect();
        Summary::try_of(&xs)
    }

    /// Per-kind concurrency rows, label-sorted.
    pub fn kind_table(&self) -> Vec<KindRow> {
        self.kind_ids
            .iter()
            .filter_map(|(name, &k)| {
                self.kinds.get(k).map(|lane| KindRow {
                    kind: name.clone(),
                    running: lane.running,
                    peak: lane.peak,
                    completed: lane.completed,
                })
            })
            .collect()
    }

    /// Backlog (queued tasks) sampled at `n` evenly spaced instants
    /// across the window — sparkline feed.
    pub fn backlog_samples(&self, n: usize) -> Vec<f64> {
        sample_step(&self.h_backlog, self.now, self.effective_window(), n)
    }

    /// Core-utilization fraction sampled across the window.
    pub fn util_samples(&self, n: usize) -> Vec<f64> {
        sample_step(&self.h_util, self.now, self.effective_window(), n)
    }
}

/// Append a step point, collapsing repeats of the same value and
/// same-instant revisions (last write at an instant wins).
fn push_step(h: &mut VecDeque<(f64, f64)>, t: f64, v: f64) {
    if let Some(&(lt, lv)) = h.back() {
        if lv == v {
            return;
        }
        if lt == t {
            h.pop_back();
            if h.back().is_some_and(|&(_, pv)| pv == v) {
                return;
            }
        }
    }
    h.push_back((t, v));
}

/// Sample a step series at `n` instants over `[now − span, now]`.
fn sample_step(h: &VecDeque<(f64, f64)>, now: f64, span: f64, n: usize) -> Vec<f64> {
    if n == 0 || h.is_empty() {
        return vec![0.0; n];
    }
    let span = if span.is_finite() && span > 0.0 { span } else { 0.0 };
    let start = now - span;
    let mut out = Vec::with_capacity(n);
    let mut it = h.iter().peekable();
    let mut cur = 0.0;
    for i in 0..n {
        let st = if n == 1 {
            now
        } else {
            start + span * (i as f64 / (n - 1) as f64)
        };
        while it.peek().is_some_and(|&&(t, _)| t <= st) {
            if let Some(&(_, v)) = it.next() {
                cur = v;
            }
        }
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev_sub(t: f64, uid: usize, kind: &str) -> ObsEvent {
        ObsEvent::TaskSubmitted {
            t,
            uid,
            slot: 0,
            local: uid,
            kind: kind.into(),
            cores: 2,
            gpus: 1,
            tx: 5.0,
            attempt: 0,
        }
    }

    fn ev_start(t: f64, uid: usize) -> ObsEvent {
        ObsEvent::TaskStarted { t, uid, slot: 0, local: uid, node: 0, cores: 2, gpus: 1 }
    }

    fn ev_done(t: f64, uid: usize) -> ObsEvent {
        ObsEvent::TaskCompleted { t, uid, slot: 0, local: uid, failed: false }
    }

    #[test]
    fn live_counters_track_the_lifecycle() {
        let mut ws = WindowStats::new(100.0);
        ws.push(&ObsEvent::CapacityOffered { t: 0.0, cores: 8, gpus: 2 });
        ws.push(&ObsEvent::WorkflowArrived {
            t: 0.0,
            slot: 0,
            workflow: "w".into(),
            arrival: 0.0,
        });
        ws.push(&ev_sub(1.0, 0, "simulation"));
        ws.push(&ev_sub(1.0, 1, "training"));
        assert_eq!(ws.queued(), 2);
        ws.push(&ev_start(2.0, 0));
        assert_eq!((ws.queued(), ws.running()), (1, 1));
        assert_eq!(ws.used(), (2, 1));
        ws.push(&ev_start(3.0, 1));
        assert_eq!(ws.used(), (4, 2));
        let table = ws.kind_table();
        assert_eq!(table.len(), 2);
        assert_eq!(table[0].kind, "simulation");
        assert_eq!(table[0].running, 1);
        ws.push(&ev_done(7.0, 0));
        ws.push(&ev_done(9.0, 1));
        assert_eq!((ws.queued(), ws.running()), (0, 0));
        assert_eq!(ws.used(), (0, 0));
        assert_eq!(ws.peaks(), (2, 2));
        assert_eq!(ws.totals().completions, 2);
        // Wait sampled at the slot's first start: 2.0 − 0.0.
        let w = ws.wait().unwrap();
        assert_eq!(w.n, 1);
        assert_eq!(w.mean, 2.0);
    }

    #[test]
    fn window_evicts_old_events() {
        let mut ws = WindowStats::new(10.0);
        ws.push(&ObsEvent::CapacityOffered { t: 0.0, cores: 4, gpus: 0 });
        for i in 0..5 {
            ws.push(&ObsEvent::WorkflowArrived {
                t: i as f64 * 4.0,
                slot: i,
                workflow: "w".into(),
                arrival: i as f64 * 4.0,
            });
        }
        // now = 16, window (6, 16]: arrivals at 8, 12, 16 survive.
        assert_eq!(ws.in_window().arrivals, 3);
        assert_eq!(ws.totals().arrivals, 5);
        // Rates clip to the stream extent (16 s < no clip here: w=10).
        assert!((ws.rate(ws.in_window().arrivals) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn kills_release_resources_and_backoff_tracks_retries() {
        let mut ws = WindowStats::new(f64::INFINITY);
        ws.push(&ObsEvent::CapacityOffered { t: 0.0, cores: 8, gpus: 2 });
        ws.push(&ev_sub(0.0, 0, "stress"));
        ws.push(&ev_start(1.0, 0));
        ws.push(&ObsEvent::NodeFault { t: 2.0, node: 0, victims: 1 });
        ws.push(&ObsEvent::TaskKilled {
            t: 2.0,
            uid: 0,
            slot: 0,
            local: 0,
            node: 0,
            attempt: 1,
            lost_core_s: 2.0,
        });
        ws.push(&ObsEvent::RetryScheduled { t: 2.0, uid: 0, due: 4.0, attempt: 1 });
        assert_eq!(ws.used(), (0, 0));
        assert_eq!((ws.running(), ws.backoff()), (0, 1));
        ws.push(&ObsEvent::TaskSubmitted {
            t: 4.0,
            uid: 0,
            slot: 0,
            local: 0,
            kind: "stress".into(),
            cores: 2,
            gpus: 1,
            tx: 5.0,
            attempt: 1,
        });
        assert_eq!((ws.queued(), ws.backoff()), (1, 0));
        assert_eq!(ws.totals().resubmissions, 1);
        ws.push(&ev_start(4.0, 0));
        ws.push(&ev_done(9.0, 0));
        assert_eq!(ws.kind_table()[0].completed, 1);
        assert_eq!(ws.totals().kills, 1);
    }

    #[test]
    fn step_sampling_holds_values_between_points() {
        let mut h = VecDeque::new();
        push_step(&mut h, 0.0, 0.0);
        push_step(&mut h, 2.0, 3.0);
        push_step(&mut h, 8.0, 1.0);
        let s = sample_step(&h, 10.0, 10.0, 5);
        // Samples at t = 0, 2.5, 5, 7.5, 10.
        assert_eq!(s, vec![0.0, 3.0, 3.0, 3.0, 1.0]);
        // Same-value repeats collapse; same-instant revisions win last.
        let mut h2 = VecDeque::new();
        push_step(&mut h2, 0.0, 1.0);
        push_step(&mut h2, 0.0, 2.0);
        push_step(&mut h2, 1.0, 2.0);
        assert_eq!(h2, VecDeque::from(vec![(0.0, 2.0)]));
    }
}
