//! Deterministic SVG renderers over a replayed event stream.
//!
//! Three hand-rolled, self-contained figures (no external templates,
//! no fonts beyond the SVG `font-family` hint):
//!
//! - [`overlap_heatmap_svg`]: the kind×kind overlap matrix as a
//!   heatmap — the visual form of the paper's heterogeneous-overlap
//!   argument (off-diagonal mass = cross-kind asynchrony);
//! - [`kind_timeline_svg`]: per-kind concurrency step timelines over
//!   the run (execution attempts, so killed work shows too);
//! - [`util_backlog_svg`]: offered-vs-used cores and the queued-task
//!   backlog on a shared time axis, with arrival-window half markers
//!   (the saturation-verdict inputs, drawn).
//!
//! ## Determinism contract
//!
//! Every function is a pure `String` of its input: fixed palette,
//! fixed geometry, all coordinates formatted with `{:.2}` and values
//! with `{:.3}` (shortest-round-trip float printing never reaches the
//! output). The same seed therefore produces byte-identical SVGs
//! across runs, machines, and wake policies — asserted in
//! `tests/obs_watch.rs` — which makes the figures safe to commit as CI
//! artifacts and diff like text.

use super::trace::{ReplayedRun, TraceAnalysis};

/// Categorical palette (Tableau 10 subset), cycled per kind.
const PALETTE: [&str; 8] = [
    "#4e79a7", "#f28e2b", "#e15759", "#76b7b2", "#59a14f", "#edc949", "#b07aa1", "#9c755f",
];

/// XML-escape a label for attribute/text positions.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '&' => out.push_str("&amp;"),
            '<' => out.push_str("&lt;"),
            '>' => out.push_str("&gt;"),
            '"' => out.push_str("&quot;"),
            '\'' => out.push_str("&apos;"),
            _ => out.push(ch),
        }
    }
    out
}

/// Heatmap cell fill: linear white → palette-blue by `frac` ∈ [0,1],
/// with integer-rounded channels so the bytes never depend on float
/// formatting.
fn heat_color(frac: f64) -> String {
    let frac = frac.clamp(0.0, 1.0);
    // #4e79a7 = (78, 121, 167).
    let ch = |hi: f64| (255.0 + (hi - 255.0) * frac).round() as u8;
    format!("rgb({},{},{})", ch(78.0), ch(121.0), ch(167.0))
}

fn svg_open(w: f64, h: f64) -> String {
    format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{w:.0}\" height=\"{h:.0}\" \
         viewBox=\"0 0 {w:.0} {h:.0}\" font-family=\"monospace\" font-size=\"11\">\n",
    )
}

/// Step polyline path (`M … H … V …`) through `(t, v)` change points,
/// holding each value to the next point and closing at `t_end`.
fn step_path(
    points: &[(f64, f64)],
    t_end: f64,
    x: impl Fn(f64) -> f64,
    y: impl Fn(f64) -> f64,
) -> String {
    let mut d = String::new();
    for (i, &(t, v)) in points.iter().enumerate() {
        if i == 0 {
            d.push_str(&format!("M {:.2} {:.2}", x(t), y(v)));
        } else {
            d.push_str(&format!(" H {:.2} V {:.2}", x(t), y(v)));
        }
    }
    if let Some(&(last_t, _)) = points.last() {
        if t_end > last_t {
            d.push_str(&format!(" H {:.2}", x(t_end)));
        }
    }
    d
}

/// Kind-overlap heatmap: cell (i,j) shaded by seconds kinds i and j
/// were simultaneously active, annotated with the value; the diagonal
/// is each kind's own active time.
pub fn overlap_heatmap_svg(a: &TraceAnalysis) -> String {
    let n = a.kinds.len();
    let cell = 64.0;
    let label_w = 150.0;
    let top = 40.0;
    let w = label_w + n as f64 * cell + 20.0;
    let h = top + n as f64 * cell + 60.0;
    let mut s = svg_open(w.max(320.0), h);
    s.push_str(&format!(
        "<text x=\"10\" y=\"20\" font-size=\"13\">kind overlap (seconds co-active) — DOA {:.3}, \
         async improvement {:.1}%</text>\n",
        a.degree_of_asynchronicity,
        a.async_improvement * 100.0,
    ));
    let max = a
        .overlap
        .iter()
        .flat_map(|row| row.iter().copied())
        .fold(0.0f64, f64::max);
    for (i, ki) in a.kinds.iter().enumerate() {
        // Row label.
        s.push_str(&format!(
            "<text x=\"{:.2}\" y=\"{:.2}\" text-anchor=\"end\">{}</text>\n",
            label_w - 8.0,
            top + i as f64 * cell + cell / 2.0 + 4.0,
            esc(&ki.kind),
        ));
        // Column label (under the grid, angled not needed for few kinds).
        s.push_str(&format!(
            "<text x=\"{:.2}\" y=\"{:.2}\" text-anchor=\"middle\">{}</text>\n",
            label_w + i as f64 * cell + cell / 2.0,
            top + n as f64 * cell + 18.0,
            esc(&ki.kind),
        ));
        for j in 0..n {
            let v = a
                .overlap
                .get(i)
                .and_then(|row| row.get(j))
                .copied()
                .unwrap_or(0.0);
            let frac = if max > 0.0 { v / max } else { 0.0 };
            let x = label_w + j as f64 * cell;
            let y = top + i as f64 * cell;
            s.push_str(&format!(
                "<rect x=\"{x:.2}\" y=\"{y:.2}\" width=\"{cell:.2}\" height=\"{cell:.2}\" \
                 fill=\"{}\" stroke=\"#ffffff\"/>\n",
                heat_color(frac),
            ));
            s.push_str(&format!(
                "<text x=\"{:.2}\" y=\"{:.2}\" text-anchor=\"middle\" fill=\"{}\">{v:.3}</text>\n",
                x + cell / 2.0,
                y + cell / 2.0 + 4.0,
                if frac > 0.55 { "#ffffff" } else { "#333333" },
            ));
        }
    }
    s.push_str("</svg>\n");
    s
}

/// Per-kind concurrency timelines: one colored step line per task
/// kind over the run's makespan, with a legend carrying each kind's
/// peak. Computed over execution attempts (kills included), matching
/// the analyzer's sweep.
pub fn kind_timeline_svg(run: &ReplayedRun) -> String {
    // Label-sorted kinds, as everywhere else.
    let mut kinds: Vec<&str> = run.intervals.iter().map(|iv| iv.kind.as_str()).collect();
    kinds.sort_unstable();
    kinds.dedup();
    let t_end = run
        .intervals
        .iter()
        .map(|iv| iv.end)
        .fold(0.0f64, f64::max)
        .max(1e-9);

    // Per-kind step series from interval deltas.
    let mut series: Vec<Vec<(f64, f64)>> = Vec::with_capacity(kinds.len());
    let mut peaks: Vec<f64> = Vec::with_capacity(kinds.len());
    let mut global_peak = 0.0f64;
    for k in &kinds {
        let mut deltas: Vec<(f64, i64)> = Vec::new();
        for iv in run.intervals.iter().filter(|iv| iv.kind == *k) {
            deltas.push((iv.start, 1));
            deltas.push((iv.end, -1));
        }
        deltas.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut pts: Vec<(f64, f64)> = vec![(0.0, 0.0)];
        let mut c = 0i64;
        let mut i = 0usize;
        let mut peak = 0.0f64;
        while i < deltas.len() {
            let t = deltas[i].0;
            while i < deltas.len() && deltas[i].0 == t {
                c += deltas[i].1;
                i += 1;
            }
            let v = c.max(0) as f64;
            peak = peak.max(v);
            pts.push((t, v));
        }
        global_peak = global_peak.max(peak);
        peaks.push(peak);
        series.push(pts);
    }
    let global_peak = global_peak.max(1.0);

    let (w, h) = (900.0, 360.0);
    let (ml, mr, mt, mb) = (60.0, 20.0, 40.0, 50.0);
    let (pw, ph) = (w - ml - mr, h - mt - mb);
    let x = |t: f64| ml + t / t_end * pw;
    let y = |v: f64| mt + ph - v / global_peak * ph;
    let mut s = svg_open(w, h + 24.0 * kinds.len() as f64);
    s.push_str(&format!(
        "<text x=\"10\" y=\"20\" font-size=\"13\">per-kind concurrency over {t_end:.3} s \
         ({} attempts)</text>\n",
        run.intervals.len(),
    ));
    // Axes.
    s.push_str(&format!(
        "<line x1=\"{ml:.2}\" y1=\"{:.2}\" x2=\"{:.2}\" y2=\"{:.2}\" stroke=\"#888888\"/>\n",
        mt + ph,
        ml + pw,
        mt + ph,
    ));
    s.push_str(&format!(
        "<line x1=\"{ml:.2}\" y1=\"{mt:.2}\" x2=\"{ml:.2}\" y2=\"{:.2}\" stroke=\"#888888\"/>\n",
        mt + ph,
    ));
    s.push_str(&format!(
        "<text x=\"{:.2}\" y=\"{:.2}\" text-anchor=\"end\">{global_peak:.0}</text>\n",
        ml - 6.0,
        mt + 10.0,
    ));
    s.push_str(&format!(
        "<text x=\"{:.2}\" y=\"{:.2}\" text-anchor=\"end\">0</text>\n",
        ml - 6.0,
        mt + ph,
    ));
    s.push_str(&format!(
        "<text x=\"{:.2}\" y=\"{:.2}\" text-anchor=\"end\">{t_end:.0} s</text>\n",
        ml + pw,
        mt + ph + 16.0,
    ));
    for (ki, pts) in series.iter().enumerate() {
        let color = PALETTE.get(ki % PALETTE.len()).copied().unwrap_or("#333333");
        s.push_str(&format!(
            "<path d=\"{}\" fill=\"none\" stroke=\"{color}\" stroke-width=\"1.5\"/>\n",
            step_path(pts, t_end, x, y),
        ));
        // Legend row under the chart.
        let ly = h + 16.0 + 24.0 * ki as f64;
        s.push_str(&format!(
            "<rect x=\"{ml:.2}\" y=\"{:.2}\" width=\"12\" height=\"12\" fill=\"{color}\"/>\n",
            ly - 10.0,
        ));
        s.push_str(&format!(
            "<text x=\"{:.2}\" y=\"{ly:.2}\">{} (peak {:.0})</text>\n",
            ml + 18.0,
            esc(kinds.get(ki).copied().unwrap_or("?")),
            peaks.get(ki).copied().unwrap_or(0.0),
        ));
    }
    s.push_str("</svg>\n");
    s
}

/// Utilization / backlog strip: offered cores (grey step) vs cores in
/// use (blue step, filled) on the top panel, queued tasks (orange
/// step) below, sharing the time axis. When the stream carries a
/// traffic header the arrival window's half and end are marked — the
/// two integration ranges behind the live SATURATED/bounded verdict.
pub fn util_backlog_svg(run: &ReplayedRun) -> String {
    use crate::metrics::{BacklogTrace, UtilizationTrace};
    let util = UtilizationTrace::from_records_capacity(&run.records, run.capacity.clone());
    let backlog = BacklogTrace::from_records(&run.records);
    let t_end = util.makespan.max(backlog.horizon).max(1e-9);

    let used: Vec<(f64, f64)> = util.points.iter().map(|&(t, c, _)| (t, c as f64)).collect();
    let offered: Vec<(f64, f64)> = if run.capacity.points.is_empty() {
        vec![(0.0, 0.0)]
    } else {
        run.capacity.points.iter().map(|&(t, c, _)| (t, c as f64)).collect()
    };
    let queued: Vec<(f64, f64)> = backlog.points.iter().map(|&(t, n, _, _)| (t, n as f64)).collect();
    let cores_max = offered
        .iter()
        .chain(used.iter())
        .map(|&(_, v)| v)
        .fold(0.0f64, f64::max)
        .max(1.0);
    let queue_max = queued.iter().map(|&(_, v)| v).fold(0.0f64, f64::max).max(1.0);

    let w = 900.0;
    let (ml, mr) = (60.0, 20.0);
    let pw = w - ml - mr;
    let (top_y, top_h) = (40.0, 180.0);
    let (bot_y, bot_h) = (top_y + top_h + 40.0, 120.0);
    let h = bot_y + bot_h + 50.0;
    let x = |t: f64| ml + t / t_end * pw;

    let mut s = svg_open(w, h);
    s.push_str(&format!(
        "<text x=\"10\" y=\"20\" font-size=\"13\">cores offered vs used, and queued-task \
         backlog, over {t_end:.3} s</text>\n",
    ));

    // Top panel: capacity + usage.
    let ty = |v: f64| top_y + top_h - v / cores_max * top_h;
    s.push_str(&format!(
        "<line x1=\"{ml:.2}\" y1=\"{:.2}\" x2=\"{:.2}\" y2=\"{:.2}\" stroke=\"#888888\"/>\n",
        top_y + top_h,
        ml + pw,
        top_y + top_h,
    ));
    s.push_str(&format!(
        "<text x=\"{:.2}\" y=\"{:.2}\" text-anchor=\"end\">{cores_max:.0}</text>\n",
        ml - 6.0,
        top_y + 10.0,
    ));
    s.push_str(&format!(
        "<text x=\"{:.2}\" y=\"{:.2}\" text-anchor=\"end\">cores</text>\n",
        ml - 6.0,
        top_y + top_h,
    ));
    // Used-cores filled area: step path closed down to the axis.
    let mut area = step_path(&used, t_end, x, ty);
    if !used.is_empty() {
        area.push_str(&format!(
            " V {:.2} H {:.2} Z",
            top_y + top_h,
            x(used.first().map_or(0.0, |&(t, _)| t)),
        ));
    }
    s.push_str(&format!(
        "<path d=\"{area}\" fill=\"#4e79a7\" fill-opacity=\"0.35\" stroke=\"none\"/>\n",
    ));
    s.push_str(&format!(
        "<path d=\"{}\" fill=\"none\" stroke=\"#4e79a7\" stroke-width=\"1.5\"/>\n",
        step_path(&used, t_end, x, ty),
    ));
    s.push_str(&format!(
        "<path d=\"{}\" fill=\"none\" stroke=\"#666666\" stroke-width=\"1.5\" \
         stroke-dasharray=\"6 3\"/>\n",
        step_path(&offered, t_end, x, ty),
    ));
    s.push_str(&format!(
        "<text x=\"{:.2}\" y=\"{:.2}\">used (cpu {:.1}%)  — offered dashed</text>\n",
        ml + 8.0,
        top_y + 14.0,
        util.mean_utilization().0 * 100.0,
    ));

    // Bottom panel: backlog.
    let by = |v: f64| bot_y + bot_h - v / queue_max * bot_h;
    s.push_str(&format!(
        "<line x1=\"{ml:.2}\" y1=\"{:.2}\" x2=\"{:.2}\" y2=\"{:.2}\" stroke=\"#888888\"/>\n",
        bot_y + bot_h,
        ml + pw,
        bot_y + bot_h,
    ));
    s.push_str(&format!(
        "<text x=\"{:.2}\" y=\"{:.2}\" text-anchor=\"end\">{queue_max:.0}</text>\n",
        ml - 6.0,
        bot_y + 10.0,
    ));
    s.push_str(&format!(
        "<text x=\"{:.2}\" y=\"{:.2}\" text-anchor=\"end\">queued</text>\n",
        ml - 6.0,
        bot_y + bot_h,
    ));
    s.push_str(&format!(
        "<path d=\"{}\" fill=\"none\" stroke=\"#f28e2b\" stroke-width=\"1.5\"/>\n",
        step_path(&queued, t_end, x, by),
    ));
    s.push_str(&format!(
        "<text x=\"{:.2}\" y=\"{:.2}\" text-anchor=\"end\">{t_end:.0} s</text>\n",
        ml + pw,
        bot_y + bot_h + 16.0,
    ));

    // Arrival-window markers across both panels.
    if let Some(aw) = run.arrival_window {
        for (t, label) in [(aw / 2.0, "w/2"), (aw, "w")] {
            if t <= t_end {
                s.push_str(&format!(
                    "<line x1=\"{0:.2}\" y1=\"{top_y:.2}\" x2=\"{0:.2}\" y2=\"{1:.2}\" \
                     stroke=\"#e15759\" stroke-dasharray=\"2 3\"/>\n",
                    x(t),
                    bot_y + bot_h,
                ));
                s.push_str(&format!(
                    "<text x=\"{:.2}\" y=\"{:.2}\" fill=\"#e15759\">{label}</text>\n",
                    x(t) + 3.0,
                    top_y - 6.0,
                ));
            }
        }
    }
    s.push_str("</svg>\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{analyze, replay};

    #[test]
    fn renders_are_wellformed_and_deterministic() {
        let evs = crate::obs::samples();
        let run = replay(&evs).unwrap();
        let a = analyze(&evs).unwrap();
        for svg in [
            overlap_heatmap_svg(&a),
            kind_timeline_svg(&run),
            util_backlog_svg(&run),
        ] {
            assert!(svg.starts_with("<svg "));
            assert!(svg.ends_with("</svg>\n"));
            // Every <text> closes and no float leaked as NaN/inf.
            assert_eq!(svg.matches("<text").count(), svg.matches("</text>").count());
            assert!(!svg.contains("NaN") && !svg.contains("inf"));
        }
        // Byte-identity: same input, same bytes.
        let run2 = replay(&evs).unwrap();
        assert_eq!(util_backlog_svg(&run), util_backlog_svg(&run2));
        assert_eq!(kind_timeline_svg(&run), kind_timeline_svg(&run2));
    }

    #[test]
    fn heat_color_endpoints() {
        assert_eq!(heat_color(0.0), "rgb(255,255,255)");
        assert_eq!(heat_color(1.0), "rgb(78,121,167)");
        assert_eq!(esc("a<b&c>\"d'"), "a&lt;b&amp;c&gt;&quot;d&apos;");
    }
}
