//! Typed engine observability: the NDJSON event stream and its sinks.
//!
//! The paper's contribution is not just *running* heterogeneous tasks
//! asynchronously but **measuring** the asynchronicity achieved. End-of-
//! run aggregates ([`RunReport`](crate::engine::RunReport) /
//! `TrafficReport`) cannot answer "how long did simulation and training
//! tasks actually overlap?" — that needs per-entity timestamped events.
//! This module provides them:
//!
//! - [`ObsEvent`]: one typed variant per engine occurrence (workflow
//!   arrival, task submit/start/complete, node fault, kill, retry,
//!   resize, autoscale decision, checkpoint — plus the traffic layer's
//!   one-shot stream header), each carrying sim-time and the relevant
//!   uids/shape/node.
//! - [`EventSink`]: where events go. The default [`NullSink`] is a
//!   disabled sink the engine skips with one branch (zero cost);
//!   [`FileSink`] buffers NDJSON lines to disk (`--emit-events PATH`);
//!   [`MemSink`] collects events in memory for tests and the analyzer.
//! - [`trace`]: the post-hoc analyzer behind `asyncflow trace` — replays
//!   a stream into the paper's overlap/asynchronicity metrics and
//!   reconstructs utilization + wait distributions from events alone.
//! - [`profile`]: wall-clock self-profiling counters (`--profile`).
//!
//! ## Wire format
//!
//! One compact JSON object per line (the cargo `machine_message`
//! pattern), serialized through the crate's deterministic
//! [`util::json`](crate::util::json) spine: object keys render in
//! `BTreeMap` order and `f64` values print shortest-round-trip, so a
//! stream parses back bit-identically and the same simulation always
//! renders the same bytes:
//!
//! ```text
//! {"ev":"task_started","cores":4,"gpus":1,"local":2,"node":0,"slot":0,"t":12.5,"uid":7}
//! ```
//!
//! ## Determinism contract
//!
//! The stream is a pure function of the simulation: events hook **state
//! transitions** (a task starting, capacity moving), never loop
//! iterations or wake-ups, so [`WakePolicy`](crate::engine::WakePolicy)
//! `Calendar` and `FullScan` — which differ wildly in driver wake counts
//! — emit byte-identical streams. The stream is *derived* state and is
//! never snapshotted (like the event calendar): a resumed run's stream,
//! concatenated after the pre-checkpoint prefix, equals the
//! uninterrupted run's stream (property-tested in `tests/obs_stream.rs`;
//! the [`ObsEvent::CheckpointTaken`] annotation marking the seam is
//! excluded from that equality).

pub mod profile;
pub mod render;
pub mod tail;
pub mod trace;
pub mod watch;
pub mod window;

use std::cell::RefCell;
use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::rc::Rc;

use crate::error::{Error, Result};
use crate::util::json::{from_u64, obj, FromJson, Json, ToJson};

/// One engine occurrence. All times are engine (simulation) seconds;
/// `uid` is the coordinator-global task uid (recycled after
/// completion), `slot` the owning workflow's registration slot, and
/// `local` the driver-local task uid (the uid visible in that member's
/// `RunReport` records).
#[derive(Debug, Clone, PartialEq)]
pub enum ObsEvent {
    /// Stream header from the traffic layer: the run's arrival window
    /// (the denominator of the `TrafficReport` backlog-saturation
    /// verdict). Emitted exactly once, before any engine event, by a
    /// *fresh* `run_traffic_resumable_obs` run — resumed legs never
    /// re-emit it, so a resume-concatenated stream still carries it
    /// exactly once and the concatenation equality holds unchanged.
    /// Raw-`Coordinator` streams (no traffic layer) have no header.
    TrafficMeta {
        /// Always 0.0 — the header precedes the simulation.
        t: f64,
        /// Arrival window in engine seconds (`TrafficReport`'s
        /// `arrival_window`).
        window: f64,
        /// Whether failure injection was configured. The live report
        /// carries a resilience ledger whenever a `FailureSpec` is set
        /// — even if zero faults fired — so a replay cannot infer the
        /// ledger's *presence* from fault events alone.
        failure: bool,
    },
    /// Offered capacity (free + busy cores/GPUs) changed — emitted once
    /// at t = 0 with the initial allocation and thereafter whenever a
    /// grow, drain, kill or graceful-shrink release moves it. Replaying
    /// these through [`CapacityTimeline::record`] rebuilds the run's
    /// capacity timeline exactly.
    ///
    /// [`CapacityTimeline::record`]: crate::metrics::CapacityTimeline::record
    CapacityOffered {
        /// Engine time of the change.
        t: f64,
        /// Offered cores after the change.
        cores: u64,
        /// Offered GPUs after the change.
        gpus: u64,
    },
    /// A registered workflow's arrival time was reached and its driver
    /// materialized.
    WorkflowArrived {
        /// Engine time of materialization (within EPS of `arrival`).
        t: f64,
        /// Registration slot.
        slot: usize,
        /// Workflow name.
        workflow: String,
        /// Nominal arrival time (exact, as registered).
        arrival: f64,
    },
    /// A task entered the scheduler queue. `attempt` is 0 for the first
    /// submission and the retry ordinal (1, 2, ...) when a killed task
    /// re-enters after its backoff.
    TaskSubmitted {
        /// Engine time of submission.
        t: f64,
        /// Coordinator-global task uid.
        uid: usize,
        /// Owning workflow slot.
        slot: usize,
        /// Driver-local task uid.
        local: usize,
        /// Task kind label (`stress`, `simulation`, `training`, ...).
        kind: String,
        /// Requested cores.
        cores: u64,
        /// Requested GPUs.
        gpus: u64,
        /// Sampled execution time (without launch overhead).
        tx: f64,
        /// 0 = first submission, n = n-th retry resubmission.
        attempt: u32,
    },
    /// The scheduler placed the task and the executor launched it.
    TaskStarted {
        /// Engine time of launch.
        t: f64,
        /// Coordinator-global task uid.
        uid: usize,
        /// Owning workflow slot.
        slot: usize,
        /// Driver-local task uid.
        local: usize,
        /// First node of the placement (spanning placements list their
        /// anchor node).
        node: usize,
        /// Placed cores.
        cores: u64,
        /// Placed GPUs.
        gpus: u64,
    },
    /// The task ran to completion and its resources were released.
    TaskCompleted {
        /// Engine time of completion.
        t: f64,
        /// Coordinator-global task uid (recycled after this event).
        uid: usize,
        /// Owning workflow slot.
        slot: usize,
        /// Driver-local task uid.
        local: usize,
        /// Executor-reported failure flag.
        failed: bool,
    },
    /// Every task of the member drained; its driver folded into a
    /// report.
    WorkflowCompleted {
        /// Engine time of the last completion.
        t: f64,
        /// Registration slot.
        slot: usize,
        /// Workflow name.
        workflow: String,
    },
    /// Failure injection hard-killed a node.
    NodeFault {
        /// Engine time of the fault.
        t: f64,
        /// Cluster node index killed.
        node: usize,
        /// In-flight tasks taken down with it.
        victims: usize,
    },
    /// An in-flight task was lost to a node fault; its partial work is
    /// discarded.
    TaskKilled {
        /// Engine time of the kill.
        t: f64,
        /// Coordinator-global task uid (stays live across the backoff).
        uid: usize,
        /// Owning workflow slot.
        slot: usize,
        /// Driver-local task uid.
        local: usize,
        /// Node the task died on.
        node: usize,
        /// Attempt count after this kill (1 = first attempt lost).
        attempt: u32,
        /// Core-seconds of partial work discarded.
        lost_core_s: f64,
    },
    /// A killed task entered retry backoff.
    RetryScheduled {
        /// Engine time of the kill that scheduled the retry.
        t: f64,
        /// Coordinator-global task uid.
        uid: usize,
        /// Engine time the resubmission becomes due.
        due: f64,
        /// Attempt count being retried.
        attempt: u32,
    },
    /// A killed task ran out of retry budget; the run fails with
    /// [`Error::RetriesExhausted`](crate::error::Error::RetriesExhausted).
    RetriesExhausted {
        /// Engine time of the final kill.
        t: f64,
        /// Coordinator-global task uid.
        uid: usize,
        /// Owning workflow slot.
        slot: usize,
        /// Attempts consumed.
        attempts: u32,
    },
    /// A timed [`ResourcePlan`](crate::pilot::ResourcePlan) resize
    /// applied (positive delta grew, negative drained).
    PilotResized {
        /// Engine time the resize applied.
        t: f64,
        /// Node-count delta.
        delta: i64,
    },
    /// The autoscaler evaluated. Emitted for every evaluation — `acted`
    /// distinguishes a resize from a no-op (and a drain request that
    /// found nothing drainable).
    AutoscaleDecision {
        /// Engine time of the evaluation.
        t: f64,
        /// Requested node-count delta (0 = leave alone).
        delta: i64,
        /// Whether the allocation actually changed.
        acted: bool,
    },
    /// The run was preempted into a snapshot at this instant. A seam
    /// annotation, not simulation state: resume-concatenation equality
    /// is defined over streams with this variant filtered out (see
    /// [`strip_checkpoint_markers`]).
    CheckpointTaken {
        /// Engine time of the snapshot (the checkpoint target).
        t: f64,
    },
}

impl ObsEvent {
    /// Engine time the event carries.
    pub fn time(&self) -> f64 {
        match *self {
            ObsEvent::TrafficMeta { t, .. }
            | ObsEvent::CapacityOffered { t, .. }
            | ObsEvent::WorkflowArrived { t, .. }
            | ObsEvent::TaskSubmitted { t, .. }
            | ObsEvent::TaskStarted { t, .. }
            | ObsEvent::TaskCompleted { t, .. }
            | ObsEvent::WorkflowCompleted { t, .. }
            | ObsEvent::NodeFault { t, .. }
            | ObsEvent::TaskKilled { t, .. }
            | ObsEvent::RetryScheduled { t, .. }
            | ObsEvent::RetriesExhausted { t, .. }
            | ObsEvent::PilotResized { t, .. }
            | ObsEvent::AutoscaleDecision { t, .. }
            | ObsEvent::CheckpointTaken { t } => t,
        }
    }

    /// The `ev` tag this variant serializes under.
    pub fn tag(&self) -> &'static str {
        match self {
            ObsEvent::TrafficMeta { .. } => "traffic_meta",
            ObsEvent::CapacityOffered { .. } => "capacity",
            ObsEvent::WorkflowArrived { .. } => "workflow_arrived",
            ObsEvent::TaskSubmitted { .. } => "task_submitted",
            ObsEvent::TaskStarted { .. } => "task_started",
            ObsEvent::TaskCompleted { .. } => "task_completed",
            ObsEvent::WorkflowCompleted { .. } => "workflow_completed",
            ObsEvent::NodeFault { .. } => "node_fault",
            ObsEvent::TaskKilled { .. } => "task_killed",
            ObsEvent::RetryScheduled { .. } => "retry_scheduled",
            ObsEvent::RetriesExhausted { .. } => "retries_exhausted",
            ObsEvent::PilotResized { .. } => "resize",
            ObsEvent::AutoscaleDecision { .. } => "autoscale",
            ObsEvent::CheckpointTaken { .. } => "checkpoint",
        }
    }

    /// The event's compact NDJSON line (no trailing newline).
    pub fn to_ndjson(&self) -> String {
        self.to_json().to_string()
    }
}

impl ToJson for ObsEvent {
    fn to_json(&self) -> Json {
        let tag = Json::from(self.tag());
        match self {
            ObsEvent::TrafficMeta { t, window, failure } => obj([
                ("ev", tag),
                ("t", Json::from(*t)),
                ("window", Json::from(*window)),
                ("failure", Json::from(*failure)),
            ]),
            ObsEvent::CapacityOffered { t, cores, gpus } => obj([
                ("ev", tag),
                ("t", Json::from(*t)),
                ("cores", from_u64(*cores)),
                ("gpus", from_u64(*gpus)),
            ]),
            ObsEvent::WorkflowArrived { t, slot, workflow, arrival } => obj([
                ("ev", tag),
                ("t", Json::from(*t)),
                ("slot", Json::from(*slot)),
                ("workflow", Json::from(workflow.clone())),
                ("arrival", Json::from(*arrival)),
            ]),
            ObsEvent::TaskSubmitted { t, uid, slot, local, kind, cores, gpus, tx, attempt } => {
                obj([
                    ("ev", tag),
                    ("t", Json::from(*t)),
                    ("uid", Json::from(*uid)),
                    ("slot", Json::from(*slot)),
                    ("local", Json::from(*local)),
                    ("kind", Json::from(kind.clone())),
                    ("cores", from_u64(*cores)),
                    ("gpus", from_u64(*gpus)),
                    ("tx", Json::from(*tx)),
                    ("attempt", Json::from(*attempt as usize)),
                ])
            }
            ObsEvent::TaskStarted { t, uid, slot, local, node, cores, gpus } => obj([
                ("ev", tag),
                ("t", Json::from(*t)),
                ("uid", Json::from(*uid)),
                ("slot", Json::from(*slot)),
                ("local", Json::from(*local)),
                ("node", Json::from(*node)),
                ("cores", from_u64(*cores)),
                ("gpus", from_u64(*gpus)),
            ]),
            ObsEvent::TaskCompleted { t, uid, slot, local, failed } => obj([
                ("ev", tag),
                ("t", Json::from(*t)),
                ("uid", Json::from(*uid)),
                ("slot", Json::from(*slot)),
                ("local", Json::from(*local)),
                ("failed", Json::from(*failed)),
            ]),
            ObsEvent::WorkflowCompleted { t, slot, workflow } => obj([
                ("ev", tag),
                ("t", Json::from(*t)),
                ("slot", Json::from(*slot)),
                ("workflow", Json::from(workflow.clone())),
            ]),
            ObsEvent::NodeFault { t, node, victims } => obj([
                ("ev", tag),
                ("t", Json::from(*t)),
                ("node", Json::from(*node)),
                ("victims", Json::from(*victims)),
            ]),
            ObsEvent::TaskKilled { t, uid, slot, local, node, attempt, lost_core_s } => obj([
                ("ev", tag),
                ("t", Json::from(*t)),
                ("uid", Json::from(*uid)),
                ("slot", Json::from(*slot)),
                ("local", Json::from(*local)),
                ("node", Json::from(*node)),
                ("attempt", Json::from(*attempt as usize)),
                ("lost_core_s", Json::from(*lost_core_s)),
            ]),
            ObsEvent::RetryScheduled { t, uid, due, attempt } => obj([
                ("ev", tag),
                ("t", Json::from(*t)),
                ("uid", Json::from(*uid)),
                ("due", Json::from(*due)),
                ("attempt", Json::from(*attempt as usize)),
            ]),
            ObsEvent::RetriesExhausted { t, uid, slot, attempts } => obj([
                ("ev", tag),
                ("t", Json::from(*t)),
                ("uid", Json::from(*uid)),
                ("slot", Json::from(*slot)),
                ("attempts", Json::from(*attempts as usize)),
            ]),
            ObsEvent::PilotResized { t, delta } => obj([
                ("ev", tag),
                ("t", Json::from(*t)),
                ("delta", Json::from(*delta as f64)),
            ]),
            ObsEvent::AutoscaleDecision { t, delta, acted } => obj([
                ("ev", tag),
                ("t", Json::from(*t)),
                ("delta", Json::from(*delta as f64)),
                ("acted", Json::from(*acted)),
            ]),
            ObsEvent::CheckpointTaken { t } => {
                obj([("ev", tag), ("t", Json::from(*t))])
            }
        }
    }
}

/// Bounds-checked `u32` field (attempt counters).
fn req_u32(v: &Json, key: &str) -> Result<u32> {
    let n = v.req_u64(key)?;
    u32::try_from(n)
        .map_err(|_| Error::Config(format!("field '{key}': {n} overflows u32")))
}

/// `usize` field (uids, slots, node indices).
fn req_usize(v: &Json, key: &str) -> Result<usize> {
    let n = v.req_u64(key)?;
    usize::try_from(n)
        .map_err(|_| Error::Config(format!("field '{key}': {n} overflows usize")))
}

impl FromJson for ObsEvent {
    fn from_json(v: &Json) -> Result<ObsEvent> {
        let t = v.req_f64("t")?;
        Ok(match v.req_str("ev")? {
            "traffic_meta" => ObsEvent::TrafficMeta {
                t,
                window: v.req_f64("window")?,
                failure: v.req_bool("failure")?,
            },
            "capacity" => ObsEvent::CapacityOffered {
                t,
                cores: v.req_u64("cores")?,
                gpus: v.req_u64("gpus")?,
            },
            "workflow_arrived" => ObsEvent::WorkflowArrived {
                t,
                slot: req_usize(v, "slot")?,
                workflow: v.req_str("workflow")?.to_string(),
                arrival: v.req_f64("arrival")?,
            },
            "task_submitted" => ObsEvent::TaskSubmitted {
                t,
                uid: req_usize(v, "uid")?,
                slot: req_usize(v, "slot")?,
                local: req_usize(v, "local")?,
                kind: v.req_str("kind")?.to_string(),
                cores: v.req_u64("cores")?,
                gpus: v.req_u64("gpus")?,
                tx: v.req_f64("tx")?,
                attempt: req_u32(v, "attempt")?,
            },
            "task_started" => ObsEvent::TaskStarted {
                t,
                uid: req_usize(v, "uid")?,
                slot: req_usize(v, "slot")?,
                local: req_usize(v, "local")?,
                node: req_usize(v, "node")?,
                cores: v.req_u64("cores")?,
                gpus: v.req_u64("gpus")?,
            },
            "task_completed" => ObsEvent::TaskCompleted {
                t,
                uid: req_usize(v, "uid")?,
                slot: req_usize(v, "slot")?,
                local: req_usize(v, "local")?,
                failed: v.req_bool("failed")?,
            },
            "workflow_completed" => ObsEvent::WorkflowCompleted {
                t,
                slot: req_usize(v, "slot")?,
                workflow: v.req_str("workflow")?.to_string(),
            },
            "node_fault" => ObsEvent::NodeFault {
                t,
                node: req_usize(v, "node")?,
                victims: req_usize(v, "victims")?,
            },
            "task_killed" => ObsEvent::TaskKilled {
                t,
                uid: req_usize(v, "uid")?,
                slot: req_usize(v, "slot")?,
                local: req_usize(v, "local")?,
                node: req_usize(v, "node")?,
                attempt: req_u32(v, "attempt")?,
                lost_core_s: v.req_f64("lost_core_s")?,
            },
            "retry_scheduled" => ObsEvent::RetryScheduled {
                t,
                uid: req_usize(v, "uid")?,
                due: v.req_f64("due")?,
                attempt: req_u32(v, "attempt")?,
            },
            "retries_exhausted" => ObsEvent::RetriesExhausted {
                t,
                uid: req_usize(v, "uid")?,
                slot: req_usize(v, "slot")?,
                attempts: req_u32(v, "attempts")?,
            },
            "resize" => ObsEvent::PilotResized { t, delta: v.req_i64("delta")? },
            "autoscale" => ObsEvent::AutoscaleDecision {
                t,
                delta: v.req_i64("delta")?,
                acted: v.req_bool("acted")?,
            },
            "checkpoint" => ObsEvent::CheckpointTaken { t },
            other => {
                return Err(Error::Config(format!(
                    "obs: unknown event tag '{other}'"
                )))
            }
        })
    }
}

/// Drop [`ObsEvent::CheckpointTaken`] seam annotations: the equality
/// contract between a chained (checkpoint/resume) stream and the
/// uninterrupted one is defined over the simulation events only.
pub fn strip_checkpoint_markers(events: &[ObsEvent]) -> Vec<ObsEvent> {
    events
        .iter()
        .filter(|e| !matches!(e, ObsEvent::CheckpointTaken { .. }))
        .cloned()
        .collect()
}

/// Where the engine's events go.
///
/// The engine reads [`enabled`](Self::enabled) once per `run_until` and
/// skips event *construction* entirely when it returns false, so a
/// disabled sink costs one boolean per emission site. `emit` must be
/// infallible on the hot path — file sinks latch I/O errors internally
/// and surface them from [`flush`](Self::flush). The engine's own
/// flush calls (run completion, checkpoints) are best-effort pushes; the
/// CLI performs the final flush after the report prints and turns a
/// still-latched error into a visible warning plus a nonzero exit.
pub trait EventSink {
    /// Whether events should be constructed and emitted at all.
    fn enabled(&self) -> bool {
        true
    }
    /// Record one event.
    fn emit(&mut self, ev: &ObsEvent);
    /// Surface any deferred error and push buffered output to its
    /// destination.
    fn flush(&mut self) -> Result<()> {
        Ok(())
    }
}

/// The zero-cost default: reports disabled, drops everything.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl EventSink for NullSink {
    fn enabled(&self) -> bool {
        false
    }
    fn emit(&mut self, _ev: &ObsEvent) {}
}

/// In-memory sink for tests and in-process analysis.
#[derive(Debug, Default)]
pub struct MemSink {
    /// Every event emitted, in order.
    pub events: Vec<ObsEvent>,
}

impl MemSink {
    /// Empty sink.
    pub fn new() -> MemSink {
        MemSink::default()
    }

    /// The collected stream rendered as NDJSON (one line per event,
    /// trailing newline included when non-empty).
    pub fn to_ndjson(&self) -> String {
        let mut out = String::new();
        for ev in &self.events {
            let _ = writeln!(out, "{}", ev.to_json());
        }
        out
    }
}

impl EventSink for MemSink {
    fn emit(&mut self, ev: &ObsEvent) {
        self.events.push(ev.clone());
    }
}

/// Buffered NDJSON file sink (`--emit-events PATH`). Write errors are
/// latched and surfaced by `flush` — the simulation itself never aborts
/// mid-flight on a full disk. The latch is *sticky*: once a write has
/// failed, every subsequent `flush` re-reports it, so the engine's
/// best-effort mid-run flushes cannot consume the error before the CLI
/// performs its final, user-visible flush.
#[derive(Debug)]
pub struct FileSink {
    out: BufWriter<File>,
    /// First write error (kind + rendered message), held for every
    /// later `flush`.
    err: Option<(std::io::ErrorKind, String)>,
    /// Reused per-line render buffer.
    line: String,
}

impl FileSink {
    /// Create (truncate) `path` and buffer NDJSON lines into it.
    pub fn create<P: AsRef<Path>>(path: P) -> Result<FileSink> {
        let f = File::create(path)?;
        Ok(FileSink { out: BufWriter::new(f), err: None, line: String::new() })
    }

    fn latch(&mut self, e: std::io::Error) {
        if self.err.is_none() {
            self.err = Some((e.kind(), e.to_string()));
        }
    }
}

impl EventSink for FileSink {
    fn emit(&mut self, ev: &ObsEvent) {
        if self.err.is_some() {
            return;
        }
        self.line.clear();
        let _ = write!(self.line, "{}", ev.to_json());
        self.line.push('\n');
        if let Err(e) = self.out.write_all(self.line.as_bytes()) {
            self.latch(e);
        }
    }

    fn flush(&mut self) -> Result<()> {
        if self.err.is_none() {
            if let Err(e) = self.out.flush() {
                self.latch(e);
            }
        }
        match &self.err {
            Some((kind, msg)) => Err(Error::Io(std::io::Error::new(*kind, msg.clone()))),
            None => Ok(()),
        }
    }
}

/// Shared-handle sink: the caller keeps the `Rc` and hands the engine a
/// clone, so the collected events (or the open file) remain reachable
/// after the run consumes its `Coordinator` — and one stream can span
/// several chained checkpoint/resume legs.
impl<S: EventSink> EventSink for Rc<RefCell<S>> {
    fn enabled(&self) -> bool {
        self.borrow().enabled()
    }
    fn emit(&mut self, ev: &ObsEvent) {
        self.borrow_mut().emit(ev);
    }
    fn flush(&mut self) -> Result<()> {
        self.borrow_mut().flush()
    }
}

/// One event of every variant — the shared fixture for round-trip,
/// rollup, and renderer tests across the obs modules.
#[cfg(test)]
pub(crate) fn samples() -> Vec<ObsEvent> {
    vec![
        ObsEvent::TrafficMeta { t: 0.0, window: 600.0, failure: true },
        ObsEvent::CapacityOffered { t: 0.0, cores: 84, gpus: 12 },
        ObsEvent::WorkflowArrived { t: 0.0, slot: 0, workflow: "ddmd".into(), arrival: 0.0 },
        ObsEvent::TaskSubmitted {
            t: 0.5,
            uid: 3,
            slot: 0,
            local: 1,
            kind: "simulation".into(),
            cores: 4,
            gpus: 1,
            tx: 123.456,
            attempt: 0,
        },
        ObsEvent::TaskStarted { t: 0.5, uid: 3, slot: 0, local: 1, node: 2, cores: 4, gpus: 1 },
        ObsEvent::TaskCompleted { t: 124.0, uid: 3, slot: 0, local: 1, failed: false },
        ObsEvent::WorkflowCompleted { t: 124.0, slot: 0, workflow: "ddmd".into() },
        ObsEvent::NodeFault { t: 60.0, node: 2, victims: 1 },
        ObsEvent::TaskKilled {
            t: 60.0,
            uid: 3,
            slot: 0,
            local: 1,
            node: 2,
            attempt: 1,
            lost_core_s: 238.0,
        },
        ObsEvent::RetryScheduled { t: 60.0, uid: 3, due: 65.0, attempt: 1 },
        ObsEvent::RetriesExhausted { t: 99.0, uid: 3, slot: 0, attempts: 4 },
        ObsEvent::PilotResized { t: 100.0, delta: -2 },
        ObsEvent::AutoscaleDecision { t: 150.0, delta: 1, acted: true },
        ObsEvent::CheckpointTaken { t: 200.0 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_variant_round_trips_through_ndjson() {
        for ev in samples() {
            let line = ev.to_ndjson();
            assert!(!line.contains('\n'), "compact single line: {line}");
            let back = ObsEvent::from_json(&Json::parse(&line).unwrap()).unwrap();
            assert_eq!(back, ev, "via {line}");
            // Deterministic rendering: re-serializing is byte-identical.
            assert_eq!(back.to_ndjson(), line);
        }
    }

    #[test]
    fn tags_are_unique_and_times_accessible() {
        let evs = samples();
        let tags: std::collections::BTreeSet<&str> =
            evs.iter().map(|e| e.tag()).collect();
        assert_eq!(tags.len(), evs.len(), "one tag per variant");
        assert_eq!(evs[0].time(), 0.0);
        assert_eq!(evs.last().unwrap().time(), 200.0);
    }

    #[test]
    fn unknown_tag_and_missing_fields_error() {
        let bad = Json::parse(r#"{"ev":"nope","t":1}"#).unwrap();
        assert!(ObsEvent::from_json(&bad).is_err());
        let missing = Json::parse(r#"{"ev":"task_started","t":1}"#).unwrap();
        assert!(ObsEvent::from_json(&missing).is_err());
    }

    #[test]
    fn null_sink_is_disabled_and_mem_sink_collects() {
        let null = NullSink;
        assert!(!null.enabled());
        let mut mem = MemSink::new();
        assert!(mem.enabled());
        for ev in samples() {
            mem.emit(&ev);
        }
        assert_eq!(mem.events.len(), samples().len());
        assert_eq!(mem.to_ndjson().lines().count(), samples().len());
        assert!(mem.flush().is_ok());
    }

    #[test]
    fn shared_handle_sink_forwards() {
        let rc = Rc::new(RefCell::new(MemSink::new()));
        let mut handle: Box<dyn EventSink> = Box::new(Rc::clone(&rc));
        assert!(handle.enabled());
        handle.emit(&ObsEvent::CheckpointTaken { t: 1.0 });
        handle.flush().unwrap();
        assert_eq!(rc.borrow().events.len(), 1);
    }

    #[test]
    fn checkpoint_markers_strip() {
        let evs = samples();
        let stripped = strip_checkpoint_markers(&evs);
        assert_eq!(stripped.len(), evs.len() - 1);
        assert!(stripped
            .iter()
            .all(|e| !matches!(e, ObsEvent::CheckpointTaken { .. })));
    }

    #[test]
    fn file_sink_writes_parseable_ndjson() {
        let dir = std::env::temp_dir();
        let path = dir.join("asyncflow_obs_filesink_test.ndjson");
        {
            let mut fs = FileSink::create(&path).unwrap();
            for ev in samples() {
                fs.emit(&ev);
            }
            fs.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let evs: Vec<ObsEvent> = text
            .lines()
            .map(|l| ObsEvent::from_json(&Json::parse(l).unwrap()).unwrap())
            .collect();
        assert_eq!(evs, samples());
        let _ = std::fs::remove_file(&path);
    }
}
