//! The `asyncflow watch` console: a zero-dependency terminal dashboard
//! over a recorded or live-tailed event stream.
//!
//! Three layers, separable on purpose:
//!
//! - [`Headline`] / [`headline`]: the run-so-far reduced to the same
//!   figures [`TrafficReport`](crate::traffic::TrafficReport) prints —
//!   computed from a [`ReplayedRun`] with the *same folds in the same
//!   order* as the live report, so every float is bit-identical to
//!   what the live run would print (`tests/obs_watch.rs` asserts
//!   equality down to `f64::to_bits`).
//! - [`render_frame`]: one dashboard frame from a
//!   [`WindowStats`] — sparklines, lane rates, per-kind concurrency.
//!   Pure string building over sim-time rollups: byte-deterministic
//!   per stream, which is what lets `--once` run in CI.
//! - [`follow`]: the only impure part — a wall-clock poll loop that
//!   tails a growing file and repaints. Quarantined here (and
//!   allow-listed for the DET003 lint) so everything above stays
//!   clock-free.

use std::path::Path;

use crate::util::error::Result;
use crate::util::stats::Summary;

use super::tail::TailFollower;
use super::trace::{replay, ReplayedRun};
use super::window::WindowStats;
use super::ObsEvent;

/// The live `TrafficReport` figures reconstructed from a stream.
///
/// Field-for-field these reproduce the live report's numbers using the
/// identical expressions (`metrics::throughput`, `BacklogTrace` means,
/// `UtilizationTrace::mean_utilization`, `Summary` over slot-ordered
/// waits), so a recorded stream answers "what would the run have
/// printed" exactly — not approximately.
#[derive(Debug, Clone)]
pub struct Headline {
    /// Workflows that arrived.
    pub n_workflows: usize,
    /// Completed task records.
    pub n_tasks: usize,
    /// Records flagged failed. (The live report counts the engine's
    /// `failed_tasks`; these agree on any complete stream.)
    pub failed_tasks: usize,
    /// Tasks submitted but not completed by stream end.
    pub n_unfinished: usize,
    /// Last task finish time.
    pub makespan: f64,
    /// Time-integrated core utilization against offered capacity.
    pub cpu_utilization: f64,
    /// ... and GPU utilization.
    pub gpu_utilization: f64,
    /// Completed tasks per second over the makespan.
    pub task_throughput: f64,
    /// Completed workflows per second over the makespan.
    pub workflow_throughput: f64,
    /// Time-averaged queued tasks over the horizon.
    pub mean_backlog_tasks: f64,
    /// Peak queued (tasks, cores, gpus).
    pub peak_backlog: (u64, u64, u64),
    /// Arrival window from the stream header (`None` for raw
    /// coordinator streams).
    pub arrival_window: Option<f64>,
    /// Mean backlog over the first half of the arrival window.
    pub backlog_first_half: Option<f64>,
    /// ... and the second half (the saturation signal).
    pub backlog_second_half: Option<f64>,
    /// Wait distribution across workflows (slot order).
    pub wait: Option<Summary>,
    /// TTX distribution across workflows (slot order).
    pub ttx: Option<Summary>,
    /// Resilience ledger re-accumulated in stream order.
    pub ledger: Option<crate::failure::ResilienceStats>,
}

impl Headline {
    /// Second-half over first-half mean backlog (the live report's
    /// growth signal); `None` without an arrival window.
    pub fn backlog_growth(&self) -> Option<f64> {
        match (self.backlog_second_half, self.backlog_first_half) {
            (Some(s), Some(f)) => Some(s / f.max(1e-9)),
            _ => None,
        }
    }

    /// The live report's saturation heuristic; `None` without an
    /// arrival window.
    pub fn is_saturated(&self) -> Option<bool> {
        match (self.backlog_second_half, self.backlog_first_half) {
            (Some(s), Some(f)) => Some(s > 2.0 * f.max(0.5)),
            _ => None,
        }
    }

    /// Multi-line summary mirroring `TrafficReport::render`'s formats
    /// line for line, so live and replayed output diff cleanly.
    pub fn render(&self) -> String {
        let mut s = String::new();
        match self.arrival_window {
            Some(w) => s.push_str(&format!(
                "traffic: {} workflows ({} tasks, {} failed) over a {:.0} s arrival window\n",
                self.n_workflows, self.n_tasks, self.failed_tasks, w,
            )),
            None => s.push_str(&format!(
                "trace: {} workflows ({} tasks, {} failed)\n",
                self.n_workflows, self.n_tasks, self.failed_tasks,
            )),
        }
        if let Some(w) = &self.wait {
            s.push_str(&format!(
                "  wait    mean {:>8.1} s  p50 {:>8.1}  p95 {:>8.1}  p99 {:>8.1}  max {:>8.1}\n",
                w.mean, w.p50, w.p95, w.p99, w.max
            ));
        }
        if let Some(w) = &self.ttx {
            s.push_str(&format!(
                "  TTX     mean {:>8.1} s  p50 {:>8.1}  p95 {:>8.1}  p99 {:>8.1}  max {:>8.1}\n",
                w.mean, w.p50, w.p95, w.p99, w.max
            ));
        }
        match self.backlog_growth() {
            Some(g) => s.push_str(&format!(
                "  backlog mean {:.1} tasks  peak {} tasks / {} cores / {} gpus  half-window growth {:.2}x ({})\n",
                self.mean_backlog_tasks,
                self.peak_backlog.0,
                self.peak_backlog.1,
                self.peak_backlog.2,
                g,
                if self.is_saturated() == Some(true) { "SATURATED" } else { "bounded" },
            )),
            None => s.push_str(&format!(
                "  backlog mean {:.1} tasks  peak {} tasks / {} cores / {} gpus\n",
                self.mean_backlog_tasks,
                self.peak_backlog.0,
                self.peak_backlog.1,
                self.peak_backlog.2,
            )),
        }
        s.push_str(&format!(
            "  makespan {:.0} s  throughput {:.4} wf/s, {:.3} tasks/s  cpu {:.1}%  gpu {:.1}%\n",
            self.makespan,
            self.workflow_throughput,
            self.task_throughput,
            self.cpu_utilization * 100.0,
            self.gpu_utilization * 100.0,
        ));
        if let Some(r) = &self.ledger {
            s.push_str(&format!(
                "  resilience: {} node failures, {} tasks killed, {} retries ({} exhausted)\n",
                r.failures_injected, r.tasks_killed, r.retries_scheduled, r.retries_exhausted,
            ));
            let delivered = r.goodput_core_s + r.lost_core_s;
            s.push_str(&format!(
                "    goodput {:.0} core-s / {:.0} gpu-s; lost {:.0} core-s / {:.0} gpu-s ({:.1}% of delivered core-time wasted)\n",
                r.goodput_core_s,
                r.goodput_gpu_s,
                r.lost_core_s,
                r.lost_gpu_s,
                if delivered > 0.0 { r.lost_core_s / delivered * 100.0 } else { 0.0 },
            ));
        }
        if self.n_unfinished > 0 {
            s.push_str(&format!(
                "  note: {} tasks unfinished at stream end (live or truncated stream)\n",
                self.n_unfinished,
            ));
        }
        s
    }
}

/// Reduce a replayed run to the live report's headline figures. See
/// [`Headline`] for the bit-equality contract.
pub fn headline(run: &ReplayedRun) -> Headline {
    use crate::metrics::{throughput, BacklogTrace, UtilizationTrace};
    let util = UtilizationTrace::from_records_capacity(&run.records, run.capacity.clone());
    let (cpu_utilization, gpu_utilization) = util.mean_utilization();
    let makespan = run.records.iter().map(|r| r.finished).fold(0.0, f64::max);
    let workflow_throughput = if makespan > 0.0 {
        run.arrivals.len() as f64 / makespan
    } else {
        0.0
    };
    let backlog = BacklogTrace::from_records(&run.records);
    let (backlog_first_half, backlog_second_half) = match run.arrival_window {
        Some(w) => {
            let half = w / 2.0;
            (
                Some(backlog.mean_tasks_between(0.0, half)),
                Some(backlog.mean_tasks_between(half, w)),
            )
        }
        None => (None, None),
    };
    Headline {
        n_workflows: run.arrivals.len(),
        n_tasks: run.records.len(),
        failed_tasks: run.records.iter().filter(|r| r.failed).count(),
        n_unfinished: run.n_unfinished,
        makespan,
        cpu_utilization,
        gpu_utilization,
        task_throughput: throughput(&run.records),
        workflow_throughput,
        mean_backlog_tasks: backlog.mean_tasks(),
        peak_backlog: backlog.peak(),
        arrival_window: run.arrival_window,
        backlog_first_half,
        backlog_second_half,
        wait: Summary::try_of(&run.waits),
        ttx: Summary::try_of(&run.ttxs),
        ledger: run.ledger,
    }
}

const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Render values as a unicode sparkline scaled to `max` (values at or
/// below zero draw the lowest bar; `max <= 0` flattens everything).
pub fn sparkline(values: &[f64], max: f64) -> String {
    values
        .iter()
        .map(|&v| {
            if max <= 0.0 || !v.is_finite() || v <= 0.0 {
                SPARK[0]
            } else {
                let lvl = ((v / max) * 7.0).round();
                let lvl = if lvl < 0.0 { 0.0 } else if lvl > 7.0 { 7.0 } else { lvl };
                SPARK.get(lvl as usize).copied().unwrap_or('█')
            }
        })
        .collect()
}

/// Width of the sparkline strips in a frame.
const SPARK_W: usize = 48;

/// Render one dashboard frame from the rollups. Pure function of the
/// consumed stream (sim-time only): the same events produce the same
/// bytes, with or without `color` (which only adds ANSI SGR wrapping,
/// never changes content). `source` labels the stream in the header.
pub fn render_frame(ws: &WindowStats, source: &str, color: bool) -> String {
    let bold = |s: &str| if color { format!("\x1b[1m{s}\x1b[0m") } else { s.to_string() };
    let alert = |s: &str, on: bool| {
        if color && on {
            format!("\x1b[31;1m{s}\x1b[0m")
        } else {
            s.to_string()
        }
    };
    let mut out = String::new();
    out.push_str(&bold(&format!("asyncflow watch — {source}")));
    out.push('\n');
    let (used_c, used_g) = ws.used();
    let (off_c, off_g) = ws.offered();
    let util_pct = if off_c > 0 {
        used_c as f64 / off_c as f64 * 100.0
    } else {
        0.0
    };
    out.push_str(&format!(
        "  sim t {:>9.1} s   window {:>6.0} s   events {}\n",
        ws.now(),
        ws.effective_window(),
        ws.n_events(),
    ));
    out.push_str(&format!(
        "  capacity {used_c}/{off_c} cores  {used_g}/{off_g} gpus   cpu {util_pct:.1}%\n",
    ));
    let (peak_q, peak_r) = ws.peaks();
    out.push_str(&format!(
        "  tasks    {} queued  {} running  {} backoff   peak {}q/{}r\n",
        ws.queued(),
        ws.running(),
        ws.backoff(),
        peak_q,
        peak_r,
    ));

    // Sparklines over the trailing window.
    let bl = ws.backlog_samples(SPARK_W);
    let bl_max = bl.iter().copied().fold(0.0f64, f64::max);
    out.push_str(&format!(
        "  backlog  {}  now {:>4}  max {:>4.0}\n",
        sparkline(&bl, bl_max),
        ws.queued(),
        bl_max,
    ));
    let ut = ws.util_samples(SPARK_W);
    out.push_str(&format!(
        "  cpu util {}  now {:>4.0}%\n",
        sparkline(&ut, 1.0),
        util_pct,
    ));

    // Saturation verdict from the windowed backlog trend: same 2x rule
    // as the live report, applied to the window's two halves.
    let half = bl.len() / 2;
    let (first, second) = bl.split_at(half);
    let mean = |xs: &[f64]| {
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    };
    let (m1, m2) = (mean(first), mean(second));
    let saturated = m2 > 2.0 * m1.max(0.5);
    out.push_str(&format!(
        "  window backlog growth {:.2}x ({})\n",
        m2 / m1.max(1e-9),
        alert(
            if saturated { "SATURATED" } else { "bounded" },
            saturated
        ),
    ));

    // Lane table: cumulative, in-window, rate.
    let t = ws.totals();
    let w = ws.in_window();
    out.push_str("  lane          total   in-win    per-s\n");
    let rows: [(&str, u64, u64); 7] = [
        ("arrivals", t.arrivals, w.arrivals),
        ("submits", t.submissions + t.resubmissions, w.submissions),
        ("starts", t.starts, w.starts),
        ("completes", t.completions, w.completions),
        ("faults", t.faults, w.faults),
        ("kills", t.kills, w.kills),
        ("retries", t.retries_scheduled, w.retries),
    ];
    for (name, total, in_win) in rows {
        out.push_str(&format!(
            "  {name:<11} {total:>7}  {in_win:>7}  {:>7.3}\n",
            ws.rate(in_win),
        ));
    }

    // Per-kind concurrency.
    let kinds = ws.kind_table();
    if !kinds.is_empty() {
        out.push_str("  kind              run   peak   done\n");
        for k in &kinds {
            out.push_str(&format!(
                "  {:<15} {:>5}  {:>5}  {:>5}\n",
                k.kind, k.running, k.peak, k.completed,
            ));
        }
    }

    // Windowed latency percentiles.
    match (ws.wait(), ws.ttx()) {
        (Some(wt), Some(tx)) => out.push_str(&format!(
            "  wait p50 {:>8.1} s  p99 {:>8.1} s   TTX p50 {:>8.1} s  p99 {:>8.1} s\n",
            wt.p50, wt.p99, tx.p50, tx.p99,
        )),
        (Some(wt), None) => out.push_str(&format!(
            "  wait p50 {:>8.1} s  p99 {:>8.1} s   TTX (none in window)\n",
            wt.p50, wt.p99,
        )),
        _ => {}
    }
    if let Some((aw, failure)) = ws.meta() {
        out.push_str(&format!(
            "  stream: traffic, arrival window {:.0} s{}\n",
            aw,
            if failure { ", failure injection on" } else { "" },
        ));
    }
    out
}

/// One-shot dashboard: roll up `events`, render a plain (colorless)
/// frame, and append the [`Headline`] reconstruction below it. Replay
/// failures (e.g. a stream with no capacity point) degrade to a note
/// rather than an error — the frame itself never needs a full replay.
pub fn watch_once(events: &[ObsEvent], source: &str, window: f64) -> String {
    let mut ws = WindowStats::new(window);
    for ev in events {
        ws.push(ev);
    }
    let mut out = render_frame(&ws, source, false);
    out.push('\n');
    match replay(events) {
        Ok(run) => out.push_str(&headline(&run).render()),
        Err(e) => out.push_str(&format!("  headline unavailable: {e}\n")),
    }
    out
}

/// Follow a growing events file, repainting every `interval_s` wall
/// seconds; stops (Ok) after `max_frames` frames if given, else runs
/// until the process is interrupted. The sole wall-clock dependency in
/// the obs layer (DET003-exempt by configuration): rollups and frames
/// remain pure functions of the stream, only the repaint cadence and
/// screen clearing live here.
pub fn follow(
    path: &Path,
    window: f64,
    interval_s: f64,
    max_frames: Option<u64>,
) -> Result<()> {
    use std::io::Write;
    let mut follower = TailFollower::open(path)?;
    let mut ws = WindowStats::new(window);
    let mut fresh: Vec<ObsEvent> = Vec::new();
    let mut frames = 0u64;
    let source = path.display().to_string();
    loop {
        fresh.clear();
        let stream_note = match follower.poll(&mut fresh) {
            Ok(_) => None,
            Err(e) => Some(format!("stream error: {e}")),
        };
        for ev in &fresh {
            ws.push(ev);
        }
        let mut frame = String::from("\x1b[2J\x1b[H");
        frame.push_str(&render_frame(&ws, &source, true));
        frame.push_str(&format!(
            "  tail: {} bytes consumed, {} pending   (ctrl-c to stop)\n",
            follower.offset(),
            follower.pending_bytes(),
        ));
        if let Some(note) = &stream_note {
            frame.push_str(&format!("  {note}\n"));
        }
        let mut stdout = std::io::stdout().lock();
        let _ = stdout.write_all(frame.as_bytes());
        let _ = stdout.flush();
        drop(stdout);
        if stream_note.is_some() {
            // A malformed line never heals on retry; leave the last
            // frame (with the error) on screen and stop following.
            return Ok(());
        }
        frames += 1;
        if max_frames.is_some_and(|m| frames >= m) {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(interval_s.max(0.05)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparkline_scales_and_clamps() {
        assert_eq!(sparkline(&[0.0, 1.0], 1.0), "▁█");
        assert_eq!(sparkline(&[0.5], 1.0), "▅");
        // Everything flat when max is zero; negatives clamp low.
        assert_eq!(sparkline(&[3.0, -1.0], 0.0), "▁▁");
        // Values above max clamp to the top glyph.
        assert_eq!(sparkline(&[9.0], 1.0), "█");
    }

    #[test]
    fn frame_is_deterministic_and_color_only_wraps() {
        let evs = crate::obs::samples();
        let mut a = WindowStats::new(300.0);
        let mut b = WindowStats::new(300.0);
        for ev in &evs {
            a.push(ev);
            b.push(ev);
        }
        let fa = render_frame(&a, "s", false);
        let fb = render_frame(&b, "s", false);
        assert_eq!(fa, fb);
        // Color mode only inserts ANSI escapes.
        let fc = render_frame(&a, "s", true);
        let stripped: String = {
            let mut out = String::new();
            let mut esc = false;
            for ch in fc.chars() {
                if esc {
                    if ch == 'm' {
                        esc = false;
                    }
                } else if ch == '\x1b' {
                    esc = true;
                } else {
                    out.push(ch);
                }
            }
            out
        };
        assert_eq!(stripped, fa);
        assert!(fa.contains("asyncflow watch — s"));
        assert!(fa.contains("lane"));
    }

    #[test]
    fn watch_once_appends_a_headline() {
        let evs = crate::obs::samples();
        let out = watch_once(&evs, "sample", 0.0);
        assert!(out.contains("asyncflow watch — sample"));
        // samples() carries a traffic header, so the headline renders
        // the traffic form with an arrival window.
        assert!(out.contains("arrival window"));
    }
}
