//! Engine self-profiling (`--profile`): per-lane event counters and
//! wall-clock histograms of the hot loop's drain and scheduler rounds.
//!
//! This is the one observability surface that deliberately measures
//! **host wall time**, not engine time — calendar-lane cost regressions
//! (a scheduler round suddenly scanning the whole queue, a completion
//! drain touching too many drivers) are invisible in simulation seconds.
//! It is therefore the only `obs` module on the linter's DET003 timing
//! allowlist (`rust/lint.conf`); everything counted here is strictly
//! *outside* the deterministic simulation: enabling the profiler never
//! changes a trajectory, a report, or the event stream.
//!
//! The coordinator updates an [`EngineProfile`] through a shared
//! `Rc<RefCell<_>>` handle obtained from
//! [`Coordinator::enable_profiling`](crate::engine::Coordinator::enable_profiling),
//! so the numbers remain readable after the run consumes the
//! coordinator.

use std::time::{Duration, Instant};

use crate::util::bench::fmt_time;
use crate::util::json::{obj, Json};

/// Power-of-two-bucketed wall-time histogram: bucket `k` counts
/// durations in `[2^k, 2^(k+1))` nanoseconds (bucket 0 additionally
/// holds sub-nanosecond samples). 40 buckets cover ~18 minutes.
#[derive(Debug, Clone)]
pub struct WallHist {
    buckets: [u64; 40],
    count: u64,
    total_ns: u128,
    max_ns: u64,
}

impl Default for WallHist {
    fn default() -> WallHist {
        WallHist { buckets: [0; 40], count: 0, total_ns: 0, max_ns: 0 }
    }
}

impl WallHist {
    /// Record one sample.
    pub fn record(&mut self, d: Duration) {
        let ns = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
        let idx = if ns <= 1 {
            0
        } else {
            ((63 - ns.leading_zeros()) as usize).min(self.buckets.len() - 1)
        };
        self.buckets[idx] += 1;
        self.count += 1;
        self.total_ns += ns as u128;
        self.max_ns = self.max_ns.max(ns);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean sample in seconds (0 when empty).
    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.count as f64 / 1e9
        }
    }

    /// Largest sample in seconds.
    pub fn max_s(&self) -> f64 {
        self.max_ns as f64 / 1e9
    }

    /// Human rendering: one `[lo, hi)` row per non-empty bucket with a
    /// proportional bar.
    pub fn render(&self, label: &str, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(
            out,
            "  {label}: {} samples, mean {}, max {}",
            self.count,
            fmt_time(self.mean_s()),
            fmt_time(self.max_s()),
        );
        let peak = self.buckets.iter().copied().max().unwrap_or(0);
        for (k, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let lo = if k == 0 { 0.0 } else { (1u64 << k) as f64 / 1e9 };
            let hi = (1u64 << (k + 1)) as f64 / 1e9;
            let width = ((n as f64 / peak as f64) * 40.0).ceil() as usize;
            let _ = writeln!(
                out,
                "    [{:>9} .. {:>9})  {:>8}  {}",
                fmt_time(lo),
                fmt_time(hi),
                n,
                "#".repeat(width),
            );
        }
    }

    fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|&(_, &n)| n > 0)
            .map(|(k, &n)| {
                obj([
                    ("bucket_log2_ns", Json::from(k)),
                    ("count", crate::util::json::from_u64(n)),
                ])
            })
            .collect();
        obj([
            ("count", crate::util::json::from_u64(self.count)),
            ("mean_s", Json::from(self.mean_s())),
            ("max_s", Json::from(self.max_s())),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// Per-lane counters + hot-round timing for one engine run. Counter
/// names mirror the calendar lanes (arrival / resize / autoscale /
/// failure / retry / checkpoint) plus the driver-wake and
/// submit/start/complete flow the lanes feed.
#[derive(Debug, Clone)]
pub struct EngineProfile {
    /// Event-loop iterations driven.
    pub loop_iterations: u64,
    /// Arrival lane: workflows materialized.
    pub arrivals: u64,
    /// Resize lane: timed resizes applied.
    pub resizes: u64,
    /// Autoscale lane: evaluations performed (acted or not).
    pub autoscale_evals: u64,
    /// Failure lane: node faults fired (trace + MTBF).
    pub faults: u64,
    /// Retry lane: backoffs that elapsed and resubmitted.
    pub retries_resubmitted: u64,
    /// Checkpoint lane: snapshots taken.
    pub checkpoints: u64,
    /// Driver wakes released (calendar pops / full-scan steps).
    pub driver_wakes: u64,
    /// Tasks submitted to the scheduler (first submissions only).
    pub submissions: u64,
    /// Tasks launched onto the executor.
    pub tasks_started: u64,
    /// Completions drained.
    pub completions: u64,
    /// Scheduler rounds, wall-time histogram.
    pub sched_rounds: WallHist,
    /// Completion-drain rounds (drain + routing + folds), wall-time
    /// histogram.
    pub drain_rounds: WallHist,
    /// Host instant profiling was enabled (total-wall denominator).
    started: Instant,
}

impl Default for EngineProfile {
    fn default() -> EngineProfile {
        EngineProfile::new()
    }
}

impl EngineProfile {
    /// Fresh profile; stamps the wall-clock start.
    pub fn new() -> EngineProfile {
        EngineProfile {
            loop_iterations: 0,
            arrivals: 0,
            resizes: 0,
            autoscale_evals: 0,
            faults: 0,
            retries_resubmitted: 0,
            checkpoints: 0,
            driver_wakes: 0,
            submissions: 0,
            tasks_started: 0,
            completions: 0,
            sched_rounds: WallHist::default(),
            drain_rounds: WallHist::default(),
            started: Instant::now(),
        }
    }

    /// Wall seconds since the profile was created.
    pub fn wall_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Human table (the `--profile` output).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "engine profile ({} wall)", fmt_time(self.wall_s()));
        let _ = writeln!(out, "  lane counters:");
        for (name, n) in [
            ("loop iterations", self.loop_iterations),
            ("arrivals", self.arrivals),
            ("resizes", self.resizes),
            ("autoscale evals", self.autoscale_evals),
            ("faults", self.faults),
            ("retries resubmitted", self.retries_resubmitted),
            ("checkpoints", self.checkpoints),
            ("driver wakes", self.driver_wakes),
            ("submissions", self.submissions),
            ("tasks started", self.tasks_started),
            ("completions", self.completions),
        ] {
            let _ = writeln!(out, "    {name:<22} {n:>12}");
        }
        self.sched_rounds.render("scheduler rounds", &mut out);
        self.drain_rounds.render("drain rounds", &mut out);
        out
    }

    /// Machine-readable profile (output-only; the profile is wall-clock
    /// telemetry, never simulation state, so it has no parse path).
    pub fn to_json(&self) -> Json {
        use crate::util::json::from_u64;
        obj([
            ("wall_s", Json::from(self.wall_s())),
            ("loop_iterations", from_u64(self.loop_iterations)),
            ("arrivals", from_u64(self.arrivals)),
            ("resizes", from_u64(self.resizes)),
            ("autoscale_evals", from_u64(self.autoscale_evals)),
            ("faults", from_u64(self.faults)),
            ("retries_resubmitted", from_u64(self.retries_resubmitted)),
            ("checkpoints", from_u64(self.checkpoints)),
            ("driver_wakes", from_u64(self.driver_wakes)),
            ("submissions", from_u64(self.submissions)),
            ("tasks_started", from_u64(self.tasks_started)),
            ("completions", from_u64(self.completions)),
            ("sched_rounds", self.sched_rounds.to_json()),
            ("drain_rounds", self.drain_rounds.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_by_power_of_two() {
        let mut h = WallHist::default();
        h.record(Duration::from_nanos(1)); // bucket 0
        h.record(Duration::from_nanos(3)); // bucket 1: [2, 4)
        h.record(Duration::from_nanos(1024)); // bucket 10
        h.record(Duration::from_secs(2)); // ~2^31 ns
        assert_eq!(h.count(), 4);
        assert!(h.mean_s() > 0.0);
        assert!(h.max_s() >= 2.0);
        let mut out = String::new();
        h.render("x", &mut out);
        assert!(out.contains("4 samples"));
    }

    #[test]
    fn profile_renders_and_serializes() {
        let mut p = EngineProfile::new();
        p.loop_iterations = 7;
        p.completions = 3;
        p.sched_rounds.record(Duration::from_micros(5));
        let text = p.render();
        assert!(text.contains("loop iterations"));
        assert!(text.contains("scheduler rounds"));
        let j = p.to_json();
        assert_eq!(j.req_u64("loop_iterations").unwrap(), 7);
        assert_eq!(j.get("sched_rounds").req_u64("count").unwrap(), 1);
    }
}
