//! Post-hoc event-stream analyzer (`asyncflow trace <events.ndjson>`).
//!
//! Replays an NDJSON stream written by `--emit-events` and computes the
//! paper's asynchronicity metrics **from events alone** — no access to
//! the live engine state:
//!
//! - per-task-kind concurrency timelines (busy seconds, peak
//!   concurrency);
//! - the pairwise **overlap matrix**: how long each pair of task kinds
//!   actually ran concurrently (the paper's core question — did
//!   simulation and training overlap, or degenerate to stages?);
//! - the **degree of asynchronicity**: seconds with ≥ 2 distinct kinds
//!   active over seconds with any kind active, plus the improvement the
//!   measured schedule achieves over the sequential-stage baseline
//!   (Σ per-kind busy time run back-to-back);
//! - utilization reconstructed purely from events and cross-checked
//!   against the capacity timeline rebuilt from
//!   [`ObsEvent::CapacityOffered`] points;
//! - wait / TTX distributions per workflow.
//!
//! ## Reconstruction is exact, not advisory
//!
//! [`replay`] rebuilds the run's `TaskRecord`s (last-attempt start
//! wins, exactly like the live driver's bookkeeping under retries), the
//! capacity timeline, and per-member wait/TTX samples in the same
//! orders the live reporting pipeline uses — so utilization and wait
//! percentiles computed from a replayed stream are **bit-identical** to
//! the live `TrafficReport`'s (asserted in `tests/obs_trace.rs`). That
//! property is what makes the stream trustworthy: if an event were
//! missing or mis-timed, the reconstruction would drift.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::failure::ResilienceStats;
use crate::metrics::{CapacityTimeline, TaskRecord, UtilizationTrace};
use crate::util::json::{from_u64, obj, FromJson, Json};
use crate::util::stats::Summary;

use super::ObsEvent;

/// Parse an NDJSON stream (one event per non-blank line).
pub fn parse_stream(src: &str) -> Result<Vec<ObsEvent>> {
    let mut out = Vec::new();
    for (i, line) in src.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| Error::Config(format!("events line {}: {e}", i + 1)))?;
        out.push(
            ObsEvent::from_json(&v)
                .map_err(|e| Error::Config(format!("events line {}: {e}", i + 1)))?,
        );
    }
    Ok(out)
}

/// One execution attempt: a `task_started` closed by `task_completed`
/// or `task_killed`. Killed attempts occupy resources too, so overlap
/// and concurrency metrics are computed over attempts, while records
/// (and utilization, mirroring the live report) keep only the final
/// completed attempt.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecInterval {
    /// Task kind label.
    pub kind: String,
    /// Launch time.
    pub start: f64,
    /// Completion or kill time.
    pub end: f64,
    /// Placed cores.
    pub cores: u64,
    /// Placed GPUs.
    pub gpus: u64,
}

/// Everything [`replay`] reconstructs from a stream.
#[derive(Debug, Clone)]
pub struct ReplayedRun {
    /// Completed task records in merged-report order (workflow slot
    /// ascending, then driver-local uid ascending — the exact order the
    /// live merge produces), with last-attempt start times.
    pub records: Vec<TaskRecord>,
    /// Task kind per record (parallel to `records`; records carry no
    /// kind themselves).
    pub record_kinds: Vec<String>,
    /// Offered-capacity timeline rebuilt from `capacity` events.
    pub capacity: CapacityTimeline,
    /// `(slot, arrival)` per workflow, slot-ascending.
    pub arrivals: Vec<(usize, f64)>,
    /// Per-workflow wait (first task start − arrival), in slot order.
    pub waits: Vec<f64>,
    /// Per-workflow TTX (last completion − arrival), in slot order.
    pub ttxs: Vec<f64>,
    /// Every execution attempt (completed + killed).
    pub intervals: Vec<ExecInterval>,
    /// Events consumed.
    pub n_events: usize,
    /// Tasks submitted but not completed by stream end (0 for a
    /// completed run's stream).
    pub n_unfinished: usize,
    /// Workflows that completed.
    pub workflows_completed: usize,
    /// Node faults observed.
    pub faults: usize,
    /// Task kills observed.
    pub kills: usize,
    /// Retry resubmissions observed.
    pub retries: usize,
    /// Checkpoint markers observed.
    pub checkpoints: usize,
    /// Arrival window from the stream's [`ObsEvent::TrafficMeta`]
    /// header (`None` for raw-`Coordinator` streams, which have no
    /// traffic layer).
    pub arrival_window: Option<f64>,
    /// Goodput-vs-lost ledger re-accumulated from the stream, in stream
    /// order — the same order the live engine booked each term, so
    /// every float is bit-identical to the live
    /// [`ResilienceStats`]. `Some` when the header says failure
    /// injection was configured, or (headerless streams) when any
    /// fault-family event appears. Caveat: a stochastic fault drawn
    /// when *no* schedulable node remains bumps only the live
    /// `failures_injected` — there is no node to attribute, so no event
    /// — and that starved corner undercounts here.
    pub ledger: Option<ResilienceStats>,
}

/// Per-(slot, local) record state while replaying.
#[derive(Debug, Clone)]
struct RecState {
    kind: String,
    cores: u64,
    gpus: u64,
    submitted: f64,
    started: f64,
    finished: f64,
    failed: bool,
}

/// Replay `events` into the run's reconstructed state. Errors on a
/// stream with no capacity point (not produced by `--emit-events`) or
/// events referencing tasks never submitted.
pub fn replay(events: &[ObsEvent]) -> Result<ReplayedRun> {
    let mut capacity: Option<CapacityTimeline> = None;
    // uid -> (slot, local): uids recycle, the latest submission wins.
    let mut open: BTreeMap<usize, (usize, usize)> = BTreeMap::new();
    // uid -> in-flight execution attempt (start time).
    let mut exec_open: BTreeMap<usize, f64> = BTreeMap::new();
    let mut recs: BTreeMap<(usize, usize), RecState> = BTreeMap::new();
    let mut arrivals: BTreeMap<usize, f64> = BTreeMap::new();
    let mut intervals: Vec<ExecInterval> = Vec::new();
    let (mut faults, mut kills, mut retries, mut checkpoints) = (0, 0, 0, 0);
    let mut workflows_completed = 0usize;
    let mut arrival_window: Option<f64> = None;
    let mut failure_configured = false;
    let mut stats = ResilienceStats::default();

    let route_of = |open: &BTreeMap<usize, (usize, usize)>, uid: usize| {
        open.get(&uid).copied().ok_or_else(|| {
            Error::Config(format!("trace: event for uid {uid} before its submission"))
        })
    };

    for ev in events {
        match ev {
            ObsEvent::TrafficMeta { window, failure, .. } => {
                arrival_window = Some(*window);
                failure_configured |= *failure;
            }
            ObsEvent::CapacityOffered { t, cores, gpus } => match capacity.as_mut() {
                None => capacity = Some(CapacityTimeline::constant(*cores, *gpus)),
                Some(cap) => cap.record(*t, *cores, *gpus),
            },
            ObsEvent::WorkflowArrived { slot, arrival, .. } => {
                arrivals.insert(*slot, *arrival);
            }
            ObsEvent::TaskSubmitted {
                t, uid, slot, local, kind, cores, gpus, attempt, ..
            } => {
                open.insert(*uid, (*slot, *local));
                if *attempt > 0 {
                    retries += 1;
                } else {
                    recs.insert(
                        (*slot, *local),
                        RecState {
                            kind: kind.clone(),
                            cores: *cores,
                            gpus: *gpus,
                            submitted: *t,
                            started: f64::NAN,
                            finished: f64::NAN,
                            failed: false,
                        },
                    );
                }
            }
            ObsEvent::TaskStarted { t, uid, slot, local, .. } => {
                let r = recs.get_mut(&(*slot, *local)).ok_or_else(|| {
                    Error::Config(format!(
                        "trace: start for task ({slot},{local}) before its submission"
                    ))
                })?;
                // Retried tasks restart: the final record keeps the
                // last attempt's start, matching the live driver.
                r.started = *t;
                exec_open.insert(*uid, *t);
            }
            ObsEvent::TaskCompleted { t, uid, slot, local, failed } => {
                let (s, l) = route_of(&open, *uid)?;
                if (s, l) != (*slot, *local) {
                    return Err(Error::Config(format!(
                        "trace: completion routes uid {uid} to ({slot},{local}) \
                         but it was submitted as ({s},{l})"
                    )));
                }
                let r = recs.get_mut(&(s, l)).ok_or_else(|| {
                    Error::Config(format!(
                        "trace: completion for unknown task ({s},{l})"
                    ))
                })?;
                r.finished = *t;
                r.failed = *failed;
                // Goodput in stream order — the live engine books it as
                // each completion drains, so the float accumulation
                // order (and therefore every bit) matches.
                if r.started.is_finite() {
                    let dt = *t - r.started;
                    stats.goodput_core_s += dt * r.cores as f64;
                    stats.goodput_gpu_s += dt * r.gpus as f64;
                }
                if let Some(start) = exec_open.remove(uid) {
                    intervals.push(ExecInterval {
                        kind: r.kind.clone(),
                        start,
                        end: *t,
                        cores: r.cores,
                        gpus: r.gpus,
                    });
                }
                open.remove(uid);
            }
            ObsEvent::TaskKilled { t, uid, slot, local, .. } => {
                kills += 1;
                failure_configured = true;
                stats.tasks_killed += 1;
                if let Some(start) = exec_open.remove(uid) {
                    let kind = recs
                        .get(&(*slot, *local))
                        .map(|r| r.kind.clone())
                        .unwrap_or_default();
                    let (cores, gpus) = recs
                        .get(&(*slot, *local))
                        .map_or((0, 0), |r| (r.cores, r.gpus));
                    // Lost partial work, mirroring the live booking
                    // (`(now - started).max(0.0)` times the *requested*
                    // shape) term for term.
                    let dt = (*t - start).max(0.0);
                    stats.lost_core_s += dt * cores as f64;
                    stats.lost_gpu_s += dt * gpus as f64;
                    intervals.push(ExecInterval { kind, start, end: *t, cores, gpus });
                }
            }
            ObsEvent::WorkflowCompleted { .. } => workflows_completed += 1,
            ObsEvent::NodeFault { .. } => {
                faults += 1;
                failure_configured = true;
                stats.failures_injected += 1;
            }
            ObsEvent::CheckpointTaken { .. } => checkpoints += 1,
            ObsEvent::RetryScheduled { .. } => {
                failure_configured = true;
                stats.retries_scheduled += 1;
            }
            ObsEvent::RetriesExhausted { .. } => {
                failure_configured = true;
                stats.retries_exhausted += 1;
            }
            ObsEvent::PilotResized { .. } | ObsEvent::AutoscaleDecision { .. } => {}
        }
    }

    let capacity = capacity.ok_or_else(|| {
        Error::Config(
            "trace: stream carries no capacity events (not an --emit-events \
             stream, or truncated before t = 0)"
                .into(),
        )
    })?;

    // Records in merged order: slot-major, local-ascending (BTreeMap
    // iteration), uid re-assigned sequentially exactly like the
    // campaign merge.
    let mut records = Vec::new();
    let mut record_kinds = Vec::new();
    let mut n_unfinished = 0usize;
    for ((slot, _), r) in recs.iter() {
        if !r.finished.is_finite() {
            n_unfinished += 1;
            continue;
        }
        // `set_name`/`pipeline` carry the kind label and workflow slot
        // so a replayed run can feed renderers that group by lane
        // (`chrome_trace_records`) — no live reader depends on them.
        records.push(TaskRecord {
            uid: records.len(),
            set_idx: 0,
            set_name: r.kind.clone(),
            pipeline: *slot,
            branch: 0,
            submitted: r.submitted,
            started: r.started,
            finished: r.finished,
            cores: r.cores,
            gpus: r.gpus,
            failed: r.failed,
        });
        record_kinds.push(r.kind.clone());
    }

    // Per-workflow wait / TTX in slot order — the same member order and
    // the same folds (min over starts, max over finishes, arrival
    // fallback for empty members) as the live TrafficReport.
    let mut per_slot: BTreeMap<usize, (f64, f64)> = BTreeMap::new();
    for ((slot, _), r) in recs.iter() {
        if !r.finished.is_finite() {
            continue;
        }
        let e = per_slot
            .entry(*slot)
            .or_insert((f64::INFINITY, 0.0));
        e.0 = e.0.min(r.started);
        e.1 = e.1.max(r.finished);
    }
    let mut waits = Vec::with_capacity(arrivals.len());
    let mut ttxs = Vec::with_capacity(arrivals.len());
    for (&slot, &arrival) in arrivals.iter() {
        let (first_start, finish) = match per_slot.get(&slot) {
            Some(&(s, f)) => (s, f),
            None => (arrival, arrival),
        };
        waits.push(first_start - arrival);
        ttxs.push(finish - arrival);
    }

    Ok(ReplayedRun {
        records,
        record_kinds,
        capacity,
        arrivals: arrivals.into_iter().collect(),
        waits,
        ttxs,
        intervals,
        n_events: events.len(),
        n_unfinished,
        workflows_completed,
        faults,
        kills,
        retries,
        checkpoints,
        arrival_window,
        ledger: failure_configured.then_some(stats),
    })
}

/// Per-kind concurrency statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct KindStats {
    /// Kind label.
    pub kind: String,
    /// Completed tasks of this kind.
    pub tasks: usize,
    /// Seconds with ≥ 1 task of this kind running.
    pub active_s: f64,
    /// Integral of concurrency over time (task-seconds).
    pub busy_task_s: f64,
    /// Peak concurrent tasks.
    pub peak_concurrency: u64,
}

/// The full analysis `asyncflow trace` reports.
#[derive(Debug, Clone)]
pub struct TraceAnalysis {
    /// Events consumed.
    pub n_events: usize,
    /// Workflows that arrived.
    pub n_workflows: usize,
    /// Completed task records.
    pub n_tasks: usize,
    /// Max completion time.
    pub makespan: f64,
    /// Mean CPU utilization against offered capacity (events-only
    /// reconstruction; bit-identical to the live report).
    pub cpu_utilization: f64,
    /// Mean GPU utilization against offered capacity.
    pub gpu_utilization: f64,
    /// Whether reconstructed usage never exceeded reconstructed offered
    /// capacity at any instant (the CapacityTimeline cross-check).
    pub capacity_consistent: bool,
    /// Peak cores in use at one instant.
    pub peak_cores_used: u64,
    /// Peak GPUs in use at one instant.
    pub peak_gpus_used: u64,
    /// Offered capacity at stream end.
    pub final_capacity: (u64, u64),
    /// Wait distribution (first start − arrival) per workflow.
    pub wait: Option<Summary>,
    /// TTX distribution (finish − arrival) per workflow.
    pub ttx: Option<Summary>,
    /// Per-kind concurrency stats, kind-sorted.
    pub kinds: Vec<KindStats>,
    /// `overlap[i][j]`: seconds kinds `i` and `j` were simultaneously
    /// active (diagonal = the kind's own active seconds).
    pub overlap: Vec<Vec<f64>>,
    /// Seconds with any task running.
    pub any_active_s: f64,
    /// Seconds with ≥ 2 distinct kinds running.
    pub multi_active_s: f64,
    /// `multi_active_s / any_active_s` — the measured degree of
    /// asynchronicity (0 when nothing overlapped, i.e. stage-like).
    pub degree_of_asynchronicity: f64,
    /// Sequential-stage baseline: Σ per-kind active seconds (each kind
    /// run back-to-back with no cross-kind overlap).
    pub serial_baseline_s: f64,
    /// `1 − any_active_s / serial_baseline_s`: the makespan fraction
    /// saved versus the stage-sequential schedule (the paper's
    /// improvement metric computed over the measured trace).
    pub async_improvement: f64,
    /// Node faults observed.
    pub faults: usize,
    /// Task kills observed.
    pub kills: usize,
    /// Retry resubmissions observed.
    pub retries: usize,
    /// Checkpoint markers observed.
    pub checkpoints: usize,
}

/// Analyze a parsed stream. See [`replay`] for the reconstruction
/// semantics; the overlap/concurrency sweep runs over execution
/// attempts (killed attempts occupied resources too).
pub fn analyze(events: &[ObsEvent]) -> Result<TraceAnalysis> {
    let run = replay(events)?;
    analyze_replayed(&run)
}

/// [`analyze`] over an already-replayed run.
pub fn analyze_replayed(run: &ReplayedRun) -> Result<TraceAnalysis> {
    // Kind index, label-sorted for a stable matrix.
    let mut kind_idx: BTreeMap<&str, usize> = BTreeMap::new();
    for iv in &run.intervals {
        let next = kind_idx.len();
        kind_idx.entry(iv.kind.as_str()).or_insert(next);
    }
    // BTreeMap iteration is label-sorted but insertion order assigned
    // arbitrary indices; re-index by sorted order.
    let labels: Vec<String> = kind_idx.keys().map(|k| k.to_string()).collect();
    for (i, k) in labels.iter().enumerate() {
        if let Some(slot) = kind_idx.get_mut(k.as_str()) {
            *slot = i;
        }
    }
    let nk = labels.len();

    // Boundary sweep over execution attempts: at each event instant the
    // per-kind concurrency and the core/GPU usage change; between
    // instants they are constant.
    #[derive(Clone, Copy)]
    struct Delta {
        t: f64,
        kind: usize,
        conc: i64,
        cores: i64,
        gpus: i64,
    }
    let mut deltas: Vec<Delta> = Vec::with_capacity(run.intervals.len() * 2);
    for iv in &run.intervals {
        let k = kind_idx.get(iv.kind.as_str()).copied().unwrap_or(0);
        deltas.push(Delta {
            t: iv.start,
            kind: k,
            conc: 1,
            cores: iv.cores as i64,
            gpus: iv.gpus as i64,
        });
        deltas.push(Delta {
            t: iv.end,
            kind: k,
            conc: -1,
            cores: -(iv.cores as i64),
            gpus: -(iv.gpus as i64),
        });
    }
    deltas.sort_by(|a, b| a.t.total_cmp(&b.t));

    let mut conc = vec![0i64; nk];
    let mut busy_task_s = vec![0.0f64; nk];
    let mut active_s = vec![0.0f64; nk];
    let mut peak_conc = vec![0u64; nk];
    let mut tasks_per_kind = vec![0usize; nk];
    for k in &run.record_kinds {
        if let Some(&i) = kind_idx.get(k.as_str()) {
            tasks_per_kind[i] += 1;
        }
    }
    let mut overlap = vec![vec![0.0f64; nk]; nk];
    let (mut any_active, mut multi_active) = (0.0f64, 0.0f64);
    let (mut used_cores, mut used_gpus) = (0i64, 0i64);
    let (mut peak_cores, mut peak_gpus) = (0i64, 0i64);
    let mut capacity_consistent = true;

    let mut i = 0usize;
    while i < deltas.len() {
        let t = deltas[i].t;
        // Apply every delta at this instant.
        while i < deltas.len() && deltas[i].t == t {
            let d = deltas[i];
            conc[d.kind] += d.conc;
            used_cores += d.cores;
            used_gpus += d.gpus;
            peak_conc[d.kind] = peak_conc[d.kind].max(conc[d.kind].max(0) as u64);
            i += 1;
        }
        peak_cores = peak_cores.max(used_cores);
        peak_gpus = peak_gpus.max(used_gpus);
        // Accumulate the segment up to the next instant.
        let Some(next) = deltas.get(i) else { break };
        let seg = next.t - t;
        if seg <= 0.0 {
            continue;
        }
        let active: Vec<usize> = (0..nk).filter(|&k| conc[k] > 0).collect();
        for &k in &active {
            active_s[k] += seg;
            busy_task_s[k] += seg * conc[k] as f64;
        }
        for (ai, &a) in active.iter().enumerate() {
            overlap[a][a] += seg;
            for &b in &active[ai + 1..] {
                overlap[a][b] += seg;
                overlap[b][a] += seg;
            }
        }
        if !active.is_empty() {
            any_active += seg;
        }
        if active.len() >= 2 {
            multi_active += seg;
        }
        // Cross-check: usage must never exceed offered capacity. The
        // capacity timeline is piecewise-constant from the left, so a
        // mid-segment probe sees the value governing the segment.
        let (cap_c, cap_g) = run.capacity.at(t + seg * 0.5);
        if used_cores > cap_c as i64 || used_gpus > cap_g as i64 {
            capacity_consistent = false;
        }
    }

    let trace =
        UtilizationTrace::from_records_capacity(&run.records, run.capacity.clone());
    let (cpu_u, gpu_u) = trace.mean_utilization();
    let makespan = run
        .records
        .iter()
        .map(|r| r.finished)
        .fold(0.0f64, f64::max);
    let serial_baseline: f64 = active_s.iter().sum();
    let kinds: Vec<KindStats> = labels
        .iter()
        .enumerate()
        .map(|(k, label)| KindStats {
            kind: label.clone(),
            tasks: tasks_per_kind[k],
            active_s: active_s[k],
            busy_task_s: busy_task_s[k],
            peak_concurrency: peak_conc[k],
        })
        .collect();

    Ok(TraceAnalysis {
        n_events: run.n_events,
        n_workflows: run.arrivals.len(),
        n_tasks: run.records.len(),
        makespan,
        cpu_utilization: cpu_u,
        gpu_utilization: gpu_u,
        capacity_consistent,
        peak_cores_used: peak_cores.max(0) as u64,
        peak_gpus_used: peak_gpus.max(0) as u64,
        final_capacity: run.capacity.final_capacity(),
        wait: Summary::try_of(&run.waits),
        ttx: Summary::try_of(&run.ttxs),
        kinds,
        overlap,
        any_active_s: any_active,
        multi_active_s: multi_active,
        degree_of_asynchronicity: if any_active > 0.0 {
            multi_active / any_active
        } else {
            0.0
        },
        serial_baseline_s: serial_baseline,
        async_improvement: if serial_baseline > 0.0 {
            1.0 - any_active / serial_baseline
        } else {
            0.0
        },
        faults: run.faults,
        kills: run.kills,
        retries: run.retries,
        checkpoints: run.checkpoints,
    })
}

fn summary_json(s: &Option<Summary>) -> Json {
    match s {
        None => Json::Null,
        Some(s) => obj([
            ("n", Json::from(s.n)),
            ("mean", Json::from(s.mean)),
            ("std", Json::from(s.std)),
            ("min", Json::from(s.min)),
            ("max", Json::from(s.max)),
            ("p50", Json::from(s.p50)),
            ("p95", Json::from(s.p95)),
            ("p99", Json::from(s.p99)),
        ]),
    }
}

fn summary_line(s: &Option<Summary>) -> String {
    match s {
        None => "n=0".to_string(),
        Some(s) => format!(
            "n={} mean={:.3} p50={:.3} p95={:.3} p99={:.3} max={:.3}",
            s.n, s.mean, s.p50, s.p95, s.p99, s.max
        ),
    }
}

impl TraceAnalysis {
    /// Human-readable report (the default `asyncflow trace` output).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "trace: {} events | {} workflows | {} tasks | makespan {:.3} s",
            self.n_events, self.n_workflows, self.n_tasks, self.makespan
        );
        let _ = writeln!(
            out,
            "utilization (events-only): cpu {:.1}%  gpu {:.1}%   capacity check: {} \
             (peak used {}/{} cores, {}/{} gpus)",
            self.cpu_utilization * 100.0,
            self.gpu_utilization * 100.0,
            if self.capacity_consistent { "consistent" } else { "VIOLATED" },
            self.peak_cores_used,
            self.final_capacity.0,
            self.peak_gpus_used,
            self.final_capacity.1,
        );
        let _ = writeln!(out, "wait: {}", summary_line(&self.wait));
        let _ = writeln!(out, "ttx:  {}", summary_line(&self.ttx));
        if self.faults + self.kills + self.retries + self.checkpoints > 0 {
            let _ = writeln!(
                out,
                "resilience: {} faults, {} kills, {} retries, {} checkpoints",
                self.faults, self.kills, self.retries, self.checkpoints
            );
        }
        let _ = writeln!(out, "per-kind concurrency:");
        let _ = writeln!(
            out,
            "  {:<12} {:>8} {:>12} {:>14} {:>6}",
            "kind", "tasks", "active_s", "busy_task_s", "peak"
        );
        for k in &self.kinds {
            let _ = writeln!(
                out,
                "  {:<12} {:>8} {:>12.3} {:>14.3} {:>6}",
                k.kind, k.tasks, k.active_s, k.busy_task_s, k.peak_concurrency
            );
        }
        if self.kinds.len() > 1 {
            let _ = writeln!(out, "overlap matrix (s):");
            let mut hdr = format!("  {:<12}", "");
            for k in &self.kinds {
                let _ = write!(hdr, " {:>12}", k.kind);
            }
            let _ = writeln!(out, "{hdr}");
            for (i, k) in self.kinds.iter().enumerate() {
                let mut row = format!("  {:<12}", k.kind);
                for j in 0..self.kinds.len() {
                    let _ = write!(row, " {:>12.3}", self.overlap[i][j]);
                }
                let _ = writeln!(out, "{row}");
            }
        }
        let _ = writeln!(
            out,
            "degree of asynchronicity: {:.3}  ({:.3} s multi-kind active / {:.3} s \
             any active)",
            self.degree_of_asynchronicity, self.multi_active_s, self.any_active_s
        );
        let _ = writeln!(
            out,
            "async improvement vs sequential stages: {:.3}  (active span {:.3} s vs \
             {:.3} s staged)",
            self.async_improvement, self.any_active_s, self.serial_baseline_s
        );
        out
    }

    /// Machine-readable analysis (output-only; derived entirely from
    /// the stream, so it has no parse path).
    pub fn to_json(&self) -> Json {
        let kinds: Vec<Json> = self
            .kinds
            .iter()
            .map(|k| {
                obj([
                    ("kind", Json::from(k.kind.clone())),
                    ("tasks", Json::from(k.tasks)),
                    ("active_s", Json::from(k.active_s)),
                    ("busy_task_s", Json::from(k.busy_task_s)),
                    ("peak_concurrency", from_u64(k.peak_concurrency)),
                ])
            })
            .collect();
        let overlap: Vec<Json> = self
            .overlap
            .iter()
            .map(|row| Json::Arr(row.iter().map(|&v| Json::from(v)).collect()))
            .collect();
        obj([
            ("n_events", Json::from(self.n_events)),
            ("n_workflows", Json::from(self.n_workflows)),
            ("n_tasks", Json::from(self.n_tasks)),
            ("makespan_s", Json::from(self.makespan)),
            ("cpu_utilization", Json::from(self.cpu_utilization)),
            ("gpu_utilization", Json::from(self.gpu_utilization)),
            ("capacity_consistent", Json::from(self.capacity_consistent)),
            ("peak_cores_used", from_u64(self.peak_cores_used)),
            ("peak_gpus_used", from_u64(self.peak_gpus_used)),
            ("final_cores", from_u64(self.final_capacity.0)),
            ("final_gpus", from_u64(self.final_capacity.1)),
            ("wait", summary_json(&self.wait)),
            ("ttx", summary_json(&self.ttx)),
            ("kinds", Json::Arr(kinds)),
            ("overlap_s", Json::Arr(overlap)),
            ("any_active_s", Json::from(self.any_active_s)),
            ("multi_active_s", Json::from(self.multi_active_s)),
            (
                "degree_of_asynchronicity",
                Json::from(self.degree_of_asynchronicity),
            ),
            ("serial_baseline_s", Json::from(self.serial_baseline_s)),
            ("async_improvement", Json::from(self.async_improvement)),
            ("faults", Json::from(self.faults)),
            ("kills", Json::from(self.kills)),
            ("retries", Json::from(self.retries)),
            ("checkpoints", Json::from(self.checkpoints)),
        ])
    }

    /// Per-kind stats as CSV.
    pub fn kinds_csv(&self) -> String {
        let mut out = String::from("kind,tasks,active_s,busy_task_s,peak_concurrency\n");
        for k in &self.kinds {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                k.kind, k.tasks, k.active_s, k.busy_task_s, k.peak_concurrency
            ));
        }
        out
    }

    /// The overlap matrix as CSV (kind × kind, seconds).
    pub fn overlap_csv(&self) -> String {
        let mut out = String::from("kind");
        for k in &self.kinds {
            out.push(',');
            out.push_str(&k.kind);
        }
        out.push('\n');
        for (i, k) in self.kinds.iter().enumerate() {
            out.push_str(&k.kind);
            for j in 0..self.kinds.len() {
                out.push_str(&format!(",{}", self.overlap[i][j]));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-built stream: 2 kinds, partial overlap, one workflow.
    fn stream() -> Vec<ObsEvent> {
        vec![
            ObsEvent::CapacityOffered { t: 0.0, cores: 8, gpus: 2 },
            ObsEvent::WorkflowArrived {
                t: 0.0,
                slot: 0,
                workflow: "w".into(),
                arrival: 0.0,
            },
            ObsEvent::TaskSubmitted {
                t: 0.0,
                uid: 0,
                slot: 0,
                local: 0,
                kind: "simulation".into(),
                cores: 4,
                gpus: 1,
                tx: 10.0,
                attempt: 0,
            },
            ObsEvent::TaskSubmitted {
                t: 0.0,
                uid: 1,
                slot: 0,
                local: 1,
                kind: "training".into(),
                cores: 2,
                gpus: 1,
                tx: 10.0,
                attempt: 0,
            },
            ObsEvent::TaskStarted {
                t: 1.0,
                uid: 0,
                slot: 0,
                local: 0,
                node: 0,
                cores: 4,
                gpus: 1,
            },
            ObsEvent::TaskStarted {
                t: 6.0,
                uid: 1,
                slot: 0,
                local: 1,
                node: 0,
                cores: 2,
                gpus: 1,
            },
            ObsEvent::TaskCompleted { t: 11.0, uid: 0, slot: 0, local: 0, failed: false },
            ObsEvent::TaskCompleted { t: 16.0, uid: 1, slot: 0, local: 1, failed: false },
            ObsEvent::WorkflowCompleted { t: 16.0, slot: 0, workflow: "w".into() },
        ]
    }

    #[test]
    fn replay_reconstructs_records_and_waits() {
        let run = replay(&stream()).unwrap();
        assert_eq!(run.records.len(), 2);
        assert_eq!(run.records[0].started, 1.0);
        assert_eq!(run.records[0].finished, 11.0);
        assert_eq!(run.records[1].cores, 2);
        assert_eq!(run.waits, vec![1.0]);
        assert_eq!(run.ttxs, vec![16.0]);
        assert_eq!(run.n_unfinished, 0);
        assert_eq!(run.capacity.final_capacity(), (8, 2));
    }

    #[test]
    fn overlap_and_doa_measure_the_window() {
        let a = analyze(&stream()).unwrap();
        assert_eq!(a.kinds.len(), 2);
        assert_eq!(a.kinds[0].kind, "simulation");
        assert_eq!(a.kinds[1].kind, "training");
        // sim active [1, 11), train [6, 16): overlap [6, 11) = 5 s.
        assert!((a.overlap[0][1] - 5.0).abs() < 1e-12);
        assert!((a.any_active_s - 15.0).abs() < 1e-12);
        assert!((a.multi_active_s - 5.0).abs() < 1e-12);
        assert!((a.degree_of_asynchronicity - 5.0 / 15.0).abs() < 1e-12);
        // staged baseline 20 s vs 15 s measured span.
        assert!((a.serial_baseline_s - 20.0).abs() < 1e-12);
        assert!((a.async_improvement - 0.25).abs() < 1e-12);
        assert!(a.capacity_consistent);
        assert_eq!(a.peak_cores_used, 6);
        assert_eq!(a.peak_gpus_used, 2);
    }

    #[test]
    fn ndjson_round_trip_and_outputs() {
        let text: String = stream()
            .iter()
            .map(|e| format!("{}\n", e.to_ndjson()))
            .collect();
        let parsed = parse_stream(&text).unwrap();
        assert_eq!(parsed, stream());
        let a = analyze(&parsed).unwrap();
        let rendered = a.render();
        assert!(rendered.contains("degree of asynchronicity"));
        assert!(rendered.contains("overlap matrix"));
        let j = a.to_json();
        assert_eq!(j.req_f64("degree_of_asynchronicity").unwrap(), 5.0 / 15.0);
        assert!(a.kinds_csv().starts_with("kind,tasks"));
        assert!(a.overlap_csv().contains("simulation"));
    }

    #[test]
    fn killed_attempts_count_toward_overlap_not_records() {
        let mut evs = stream();
        // Inject a kill + retry of uid 0 before its completion.
        evs.insert(
            5,
            ObsEvent::TaskKilled {
                t: 3.0,
                uid: 0,
                slot: 0,
                local: 0,
                node: 0,
                attempt: 1,
                lost_core_s: 8.0,
            },
        );
        evs.insert(
            6,
            ObsEvent::TaskSubmitted {
                t: 4.0,
                uid: 0,
                slot: 0,
                local: 0,
                kind: "simulation".into(),
                cores: 4,
                gpus: 1,
                tx: 10.0,
                attempt: 1,
            },
        );
        evs.insert(
            7,
            ObsEvent::TaskStarted {
                t: 5.0,
                uid: 0,
                slot: 0,
                local: 0,
                node: 1,
                cores: 4,
                gpus: 1,
            },
        );
        let run = replay(&evs).unwrap();
        // Still 2 final records; the retried task keeps its last start.
        assert_eq!(run.records.len(), 2);
        assert_eq!(run.records[0].started, 5.0);
        assert_eq!(run.kills, 1);
        assert_eq!(run.retries, 1);
        // 3 execution attempts: the killed one plus two completions.
        assert_eq!(run.intervals.len(), 3);
    }

    #[test]
    fn streams_without_capacity_are_rejected() {
        let evs = vec![ObsEvent::CheckpointTaken { t: 1.0 }];
        assert!(replay(&evs).is_err());
        assert!(parse_stream("not json\n").is_err());
    }
}
