//! # asyncflow
//!
//! A reproduction of *"Asynchronous Execution of Heterogeneous Tasks in
//! ML-driven HPC Workflows"* (Pascuzzi, Kilic, Turilli, Jha — 2022) as a
//! production-grade three-layer stack:
//!
//! - **Layer 3 (this crate)**: the paper's coordination contribution — an
//!   EnTK-like Pipeline/Stage/Task workflow engine ([`entk`]), a
//!   RADICAL-Pilot-like pilot runtime ([`pilot`]) over a pluggable
//!   shape-bucketed continuous scheduler ([`sched`]), a Summit-like
//!   resource model ([`resources`]), the
//!   asynchronicity model (DOA_dep / DOA_res / WLA, Eqns 1–7) ([`model`],
//!   [`dag`]), a discrete-event simulator ([`sim`]), real executors
//!   ([`exec`]) behind one engine ([`engine`]), a streaming-traffic
//!   load generator with queueing metrics ([`traffic`]), and
//!   whole-simulation checkpoint/resume for preemptible allocations
//!   ([`checkpoint`]), deterministic failure injection with
//!   retry/backoff resilience ([`failure`]), and a
//!   determinism-contract linter over the crate's own sources
//!   ([`lint`]).
//! - **Layer 2**: JAX compute graphs (autoencoder training/inference, MD)
//!   AOT-lowered to HLO text at build time (`python/compile/`).
//! - **Layer 1**: Pallas kernels (blocked matmul, pairwise distances,
//!   Lennard-Jones forces) called by Layer 2.
//!
//! Layer 3 executes the compiled artifacts through the `runtime` module
//! (PJRT CPU client, behind the `pjrt` feature — the default build has
//! zero external dependencies); Python never runs on the workflow
//! execution path.
//!
//! ## Quick tour
//!
//! ```no_run
//! use asyncflow::prelude::*;
//!
//! // Build the paper's DeepDriveMD workflow (3 iterations).
//! let wf = asyncflow::ddmd::ddmd_workflow(&DdmdConfig::paper());
//! let cluster = ClusterSpec::summit_paper();
//!
//! // Predict with the paper's analytical model ...
//! let pred = asyncflow::model::predict(&wf, &cluster);
//! println!("WLA = {}, predicted I = {:.3}", pred.wla, pred.improvement);
//!
//! // ... and measure by simulating both execution modes.
//! let seq = asyncflow::engine::simulate(&wf, &cluster, ExecutionMode::Sequential);
//! let asy = asyncflow::engine::simulate(&wf, &cluster, ExecutionMode::Asynchronous);
//! println!("measured I = {:.3}", 1.0 - asy.makespan / seq.makespan);
//! ```

pub mod campaign;
pub mod checkpoint;
pub mod config;
pub mod dag;
pub mod ddmd;
pub mod engine;
pub mod entk;
pub mod error;
pub mod exec;
pub mod experiments;
pub mod failure;
pub mod lint;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod pilot;
pub mod resources;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod task;
pub mod traffic;
pub mod util;
pub mod workflows;

pub use error::{Error, Result};

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::dag::{Dag, DagAnalysis};
    pub use crate::ddmd::DdmdConfig;
    pub use crate::engine::{simulate, ExecutionMode, RunReport};
    pub use crate::entk::{Pipeline, Stage, Workflow};
    pub use crate::error::{Error, Result};
    pub use crate::metrics::{CapacityTimeline, UtilizationTrace};
    pub use crate::model::Prediction;
    pub use crate::pilot::{AutoscalePolicy, ResourcePlan};
    pub use crate::resources::{ClusterSpec, NodeSpec, ResourceRequest};
    pub use crate::task::{TaskSetSpec, TaskSpec};
}
