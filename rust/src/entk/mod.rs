//! EnTK-like Pipeline/Stage/Task workflow layer (substrate S11).
//!
//! The paper implements workflows on RADICAL EnTK's PST model [3]:
//! a *pipeline* is an ordered list of *stages*; a stage holds task sets
//! whose tasks may run concurrently; stages of one pipeline execute in
//! order (stage barrier); distinct pipelines execute independently —
//! which is exactly how the paper realizes asynchronicity ("we started
//! multiple executions of the DeepDriveMD workflow with different
//! starting times", §7.1; resource contention produces the stagger).
//!
//! A [`Workflow`] owns the task sets, the abstract dependency DAG used
//! by the model, and the two PST realizations the paper compares
//! (sequential = one pipeline, asynchronous = several). The engine
//! compiles either realization — or the *adaptive* task-level mode the
//! paper proposes as future work — into a set-level execution plan.

use crate::dag::{Dag, DagAnalysis};
use crate::error::{Error, Result};
use crate::task::TaskSetSpec;
use crate::util::json::{arr_of, obj, parse_arr, FromJson, Json, ToJson};

/// A stage: indices into `Workflow::sets` that share a stage barrier.
#[derive(Debug, Clone, PartialEq)]
pub struct Stage {
    pub sets: Vec<usize>,
}

impl Stage {
    pub fn of(sets: &[usize]) -> Stage {
        Stage { sets: sets.to_vec() }
    }
}

/// An ordered list of stages executed with barriers in between.
#[derive(Debug, Clone, PartialEq)]
pub struct Pipeline {
    pub name: String,
    pub stages: Vec<Stage>,
}

impl Pipeline {
    pub fn new(name: impl Into<String>) -> Pipeline {
        Pipeline { name: name.into(), stages: vec![] }
    }

    pub fn stage(mut self, sets: &[usize]) -> Pipeline {
        self.stages.push(Stage::of(sets));
        self
    }
}

impl ToJson for Pipeline {
    fn to_json(&self) -> Json {
        obj([
            ("name", Json::from(self.name.clone())),
            (
                "stages",
                Json::Arr(
                    self.stages
                        .iter()
                        .map(|st| {
                            Json::Arr(st.sets.iter().map(|&s| Json::from(s)).collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl FromJson for Pipeline {
    fn from_json(v: &Json) -> Result<Pipeline> {
        let mut stages = Vec::new();
        for st in v.req_arr("stages")? {
            let sets = st.as_arr().ok_or_else(|| {
                Error::Config("pipeline: each stage must be an array of set indices".into())
            })?;
            let mut idx = Vec::with_capacity(sets.len());
            for s in sets {
                idx.push(s.as_u64().ok_or_else(|| {
                    Error::Config("pipeline: stage entries must be set indices".into())
                })? as usize);
            }
            stages.push(Stage { sets: idx });
        }
        Ok(Pipeline { name: v.req_str("name")?.to_string(), stages })
    }
}

/// A complete workflow: task sets + dependency DAG + both PST
/// realizations.
#[derive(Debug, Clone)]
pub struct Workflow {
    pub name: String,
    /// Task sets; indices are shared with `dag` nodes.
    pub sets: Vec<TaskSetSpec>,
    /// Set-level dependency graph (node i <-> `sets[i]`).
    pub dag: Dag,
    /// Sequential realization (paper's baseline): usually one pipeline.
    pub sequential: Vec<Pipeline>,
    /// Asynchronous realization (paper's contribution): k pipelines.
    pub asynchronous: Vec<Pipeline>,
}

impl Workflow {
    /// Validate internal consistency; called by builders and config
    /// loading.
    pub fn validate(&self) -> Result<()> {
        if self.sets.len() != self.dag.len() {
            return Err(Error::InvalidWorkflow(format!(
                "{} sets but {} dag nodes",
                self.sets.len(),
                self.dag.len()
            )));
        }
        for (i, s) in self.sets.iter().enumerate() {
            if s.tasks == 0 {
                return Err(Error::InvalidWorkflow(format!("set '{}' has 0 tasks", s.name)));
            }
            if s.tx_mean <= 0.0 {
                return Err(Error::InvalidWorkflow(format!(
                    "set '{}' has non-positive TX",
                    s.name
                )));
            }
            if self.dag.name(i) != s.name {
                return Err(Error::InvalidWorkflow(format!(
                    "dag node {i} is '{}' but set is '{}'",
                    self.dag.name(i),
                    s.name
                )));
            }
        }
        for (label, real) in
            [("sequential", &self.sequential), ("asynchronous", &self.asynchronous)]
        {
            let mut seen = vec![false; self.sets.len()];
            for p in real {
                for st in &p.stages {
                    if st.sets.is_empty() {
                        return Err(Error::InvalidWorkflow(format!(
                            "{label}: empty stage in pipeline '{}'",
                            p.name
                        )));
                    }
                    for &s in &st.sets {
                        if s >= self.sets.len() {
                            return Err(Error::InvalidWorkflow(format!(
                                "{label}: stage references unknown set {s}"
                            )));
                        }
                        if std::mem::replace(&mut seen[s], true) {
                            return Err(Error::InvalidWorkflow(format!(
                                "{label}: set '{}' appears twice",
                                self.sets[s].name
                            )));
                        }
                    }
                }
            }
            if let Some(missing) = seen.iter().position(|&s| !s) {
                return Err(Error::InvalidWorkflow(format!(
                    "{label}: set '{}' not covered by any stage",
                    self.sets[missing].name
                )));
            }
        }
        Ok(())
    }

    pub fn analysis(&self) -> DagAnalysis {
        DagAnalysis::of(&self.dag)
    }

    pub fn total_tasks(&self) -> u64 {
        self.sets.iter().map(|s| s.tasks as u64).sum()
    }

    pub fn set_by_name(&self, name: &str) -> Option<&TaskSetSpec> {
        self.dag.node_by_name(name).map(|i| &self.sets[i])
    }

    /// Sum over sets of tasks x cores x TX (the workload's total
    /// core-seconds) — denominator-side input for utilization sanity
    /// checks.
    pub fn total_core_seconds(&self) -> f64 {
        self.sets
            .iter()
            .map(|s| s.tasks as f64 * s.req.cpu_cores as f64 * s.tx_mean)
            .sum()
    }

    pub fn total_gpu_seconds(&self) -> f64 {
        self.sets
            .iter()
            .map(|s| s.tasks as f64 * s.req.gpus as f64 * s.tx_mean)
            .sum()
    }
}

impl ToJson for Workflow {
    fn to_json(&self) -> Json {
        obj([
            ("name", Json::from(self.name.clone())),
            ("sets", arr_of(&self.sets)),
            ("dag", self.dag.to_json()),
            ("sequential", arr_of(&self.sequential)),
            ("asynchronous", arr_of(&self.asynchronous)),
        ])
    }
}

impl FromJson for Workflow {
    fn from_json(v: &Json) -> Result<Workflow> {
        let wf = Workflow {
            name: v.req_str("name")?.to_string(),
            sets: parse_arr(v, "sets")?,
            dag: Dag::from_json(v.get("dag"))?,
            sequential: parse_arr(v, "sequential")?,
            asynchronous: parse_arr(v, "asynchronous")?,
        };
        wf.validate()?;
        Ok(wf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::ResourceRequest;

    fn tiny_workflow() -> Workflow {
        let mut dag = Dag::new();
        let a = dag.add_node("A");
        let b = dag.add_node("B");
        let c = dag.add_node("C");
        dag.add_edge(a, b).unwrap();
        dag.add_edge(a, c).unwrap();
        Workflow {
            name: "tiny".into(),
            sets: vec![
                TaskSetSpec::new("A", 2, ResourceRequest::new(1, 0), 10.0),
                TaskSetSpec::new("B", 2, ResourceRequest::new(1, 0), 20.0),
                TaskSetSpec::new("C", 2, ResourceRequest::new(1, 0), 20.0),
            ],
            dag,
            sequential: vec![Pipeline::new("seq").stage(&[0]).stage(&[1, 2])],
            asynchronous: vec![
                Pipeline::new("p0").stage(&[0]).stage(&[1]),
                Pipeline::new("p1").stage(&[2]),
            ],
        }
    }

    #[test]
    fn valid_workflow_passes() {
        tiny_workflow().validate().unwrap();
    }

    #[test]
    fn rejects_uncovered_set() {
        let mut wf = tiny_workflow();
        wf.sequential = vec![Pipeline::new("seq").stage(&[0]).stage(&[1])];
        assert!(wf.validate().is_err());
    }

    #[test]
    fn rejects_duplicate_set() {
        let mut wf = tiny_workflow();
        wf.asynchronous = vec![
            Pipeline::new("p0").stage(&[0]).stage(&[1, 1]),
            Pipeline::new("p1").stage(&[2]),
        ];
        assert!(wf.validate().is_err());
    }

    #[test]
    fn rejects_name_mismatch() {
        let mut wf = tiny_workflow();
        wf.sets[1].name = "Z".into();
        assert!(wf.validate().is_err());
    }

    #[test]
    fn workflow_round_trips_through_json() {
        let wf = tiny_workflow();
        let wire = wf.to_json().to_string();
        let back =
            Workflow::from_json(&crate::util::json::Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.name, wf.name);
        assert_eq!(back.sets.len(), wf.sets.len());
        for (a, b) in wf.sets.iter().zip(&back.sets) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.tasks, b.tasks);
            assert_eq!(a.req, b.req);
            assert_eq!(a.tx_mean, b.tx_mean);
            assert_eq!(a.tx_sigma_frac, b.tx_sigma_frac);
            assert_eq!(a.kind, b.kind);
        }
        assert_eq!(back.dag, wf.dag);
        assert_eq!(back.sequential, wf.sequential);
        assert_eq!(back.asynchronous, wf.asynchronous);
        back.validate().unwrap();
    }

    #[test]
    fn totals() {
        let wf = tiny_workflow();
        assert_eq!(wf.total_tasks(), 6);
        assert!((wf.total_core_seconds() - (2.0 * 10.0 + 2.0 * 20.0 + 2.0 * 20.0)).abs() < 1e-12);
        assert_eq!(wf.total_gpu_seconds(), 0.0);
        assert_eq!(wf.set_by_name("B").unwrap().tx_mean, 20.0);
        assert!(wf.set_by_name("ZZ").is_none());
    }
}
