//! DOA_dep analysis (§5.1): independent-branch discovery, ranks,
//! critical path.
//!
//! The paper defines the dependency-permitted degree of asynchronicity
//! as *the number of independent execution branches minus 1*, with
//! branches discovered by depth-first search. Operationally:
//!
//! - a linear chain is one branch (DOA_dep = 0, Fig. 2a);
//! - every fork with out-degree d spawns d-1 additional branches
//!   (Fig. 2b: 1 fork -> DOA_dep = 1; Fig. 2c: forks of 2,2,2 ->
//!   DOA_dep = 4);
//! - disconnected components are independent branches (Fig. 2d:
//!   edge-less DG with n+1 nodes -> DOA_dep = n);
//! - a join (in-degree > 1) merges paths: the join node and its
//!   descendants continue the lowest-indexed contributing branch.
//!
//! `branches = #components + sum_v max(0, outdeg(v)-1)
//!             - sum_v max(0, indeg(v)-1)`
//! (floored at #components), and `DOA_dep = branches - 1`. Forks open
//! diverging paths; joins merge them back (Fig. 3b: forks at T0 and T2
//! open three paths, the T4/T5 -> T7 join closes one of the four raw
//! segments, giving the paper's three independent branches). Branch
//! *membership* per node is what the engine uses to measure concurrent
//! branch activity from execution traces (§5.2); note the number of
//! distinct membership segments can exceed the branch count when joins
//! are present.

use super::Dag;

/// Per-node branch assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct BranchDecomposition {
    /// branch id for every node.
    pub branch_of: Vec<usize>,
    num_branches: usize,
}

impl BranchDecomposition {
    pub fn count(&self) -> usize {
        self.num_branches
    }

    /// Node lists per branch.
    pub fn members(&self) -> Vec<Vec<usize>> {
        let mut out = vec![vec![]; self.num_branches];
        for (v, &b) in self.branch_of.iter().enumerate() {
            out[b].push(v);
        }
        out
    }
}

/// Full dependency analysis of a workflow DG.
#[derive(Debug, Clone)]
pub struct DagAnalysis {
    /// Breadth-first level of each node (max parent rank + 1).
    pub ranks: Vec<usize>,
    pub num_ranks: usize,
    pub branches: BranchDecomposition,
    /// The paper's DOA_dep = branches - 1.
    pub doa_dep: usize,
    /// Fork nodes (out-degree > 1).
    pub forks: Vec<usize>,
    /// Join nodes (in-degree > 1).
    pub joins: Vec<usize>,
}

impl DagAnalysis {
    pub fn of(dag: &Dag) -> DagAnalysis {
        let order = dag
            .topo_order()
            .expect("Dag maintains acyclicity at insertion");

        // Ranks: longest path from any root (standard BFS level for
        // stage construction).
        let mut ranks = vec![0usize; dag.len()];
        for &v in &order {
            for &p in dag.parents(v) {
                ranks[v] = ranks[v].max(ranks[p] + 1);
            }
        }
        let num_ranks = ranks.iter().max().map_or(0, |m| m + 1);

        // Branch assignment by DFS: first child inherits the parent's
        // branch, later children open new branches; joins keep the
        // branch of their lowest-branch parent (processed in topo order
        // so parents are assigned first).
        let mut branch_of = vec![usize::MAX; dag.len()];
        let mut next_branch = 0usize;
        for &v in &order {
            if branch_of[v] == usize::MAX {
                if dag.parents(v).is_empty() {
                    // Root of a component: new branch.
                    branch_of[v] = next_branch;
                    next_branch += 1;
                } else {
                    // Joins / non-first children handled below via parents;
                    // if still unassigned here, inherit min parent branch.
                    branch_of[v] = dag
                        .parents(v)
                        .iter()
                        .map(|&p| branch_of[p])
                        .min()
                        .unwrap();
                }
            }
            // Assign children: first unassigned child continues v's
            // branch; every further unassigned child starts a new one.
            let mut continued = false;
            for &c in dag.children(v) {
                if branch_of[c] != usize::MAX {
                    continue;
                }
                if dag.in_degree(c) > 1 {
                    // Join: defer to topo processing (min parent branch).
                    continue;
                }
                if !continued {
                    branch_of[c] = branch_of[v];
                    continued = true;
                } else {
                    branch_of[c] = next_branch;
                    next_branch += 1;
                }
            }
        }

        // Renumber branches densely in order of first appearance.
        let mut remap = vec![usize::MAX; next_branch];
        let mut dense = 0usize;
        for &v in &order {
            let b = branch_of[v];
            if remap[b] == usize::MAX {
                remap[b] = dense;
                dense += 1;
            }
        }
        for b in branch_of.iter_mut() {
            *b = remap[*b];
        }

        // DOA_dep closed form: components + fork excess - join excess,
        // floored at the component count.
        let comp_count = dag
            .components()
            .iter()
            .copied()
            .max()
            .map_or(0, |m| m + 1);
        let fork_excess: usize = (0..dag.len())
            .map(|v| dag.out_degree(v).saturating_sub(1))
            .sum();
        let join_excess: usize = (0..dag.len())
            .map(|v| dag.in_degree(v).saturating_sub(1))
            .sum();
        let branches_closed_form =
            (comp_count + fork_excess).saturating_sub(join_excess).max(comp_count);

        let forks = (0..dag.len()).filter(|&v| dag.out_degree(v) > 1).collect();
        let joins = (0..dag.len()).filter(|&v| dag.in_degree(v) > 1).collect();

        DagAnalysis {
            ranks,
            num_ranks,
            branches: BranchDecomposition { branch_of, num_branches: dense },
            doa_dep: branches_closed_form.saturating_sub(1),
            forks,
            joins,
        }
    }

    /// Nodes grouped by rank (stage construction for sequential mode).
    pub fn rank_groups(&self) -> Vec<Vec<usize>> {
        let mut out = vec![vec![]; self.num_ranks];
        for (v, &r) in self.ranks.iter().enumerate() {
            out[r].push(v);
        }
        out
    }

    /// Critical path value given per-node durations: the longest
    /// root-to-leaf duration sum (infinite-resource lower bound on TTX;
    /// the Eqn. 3 "max over branches" generalizes this).
    pub fn critical_path(&self, dag: &Dag, duration: &[f64]) -> f64 {
        assert_eq!(duration.len(), dag.len());
        let order = dag.topo_order().unwrap();
        let mut best = vec![0.0f64; dag.len()];
        let mut answer = 0.0f64;
        for &v in &order {
            let start = dag
                .parents(v)
                .iter()
                .map(|&p| best[p])
                .fold(0.0f64, f64::max);
            best[v] = start + duration[v];
            answer = answer.max(best[v]);
        }
        answer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::figures;
    use crate::util::prop::check_bool;
    use crate::util::rng::Rng;

    #[test]
    fn branch_count_matches_membership_everywhere() {
        for dag in [
            figures::chain(5),
            figures::fig2b(),
            figures::fig2c(),
            figures::edgeless(4),
        ] {
            let a = DagAnalysis::of(&dag);
            let distinct: std::collections::BTreeSet<_> =
                a.branches.branch_of.iter().copied().collect();
            assert_eq!(
                distinct.len(),
                a.branches.count(),
                "branch ids must be dense for {dag:?}"
            );
            // No joins in the Fig. 2 graphs: membership == closed form.
            assert_eq!(a.branches.count(), a.doa_dep + 1);
        }
    }

    #[test]
    fn fork_and_join_detection() {
        let mut d = Dag::new();
        let t: Vec<_> = (0..4).map(|i| d.add_node(format!("T{i}"))).collect();
        d.add_edge(t[0], t[1]).unwrap();
        d.add_edge(t[0], t[2]).unwrap();
        d.add_edge(t[1], t[3]).unwrap();
        d.add_edge(t[2], t[3]).unwrap(); // diamond
        let a = DagAnalysis::of(&d);
        assert_eq!(a.forks, vec![0]);
        assert_eq!(a.joins, vec![3]);
        // Fork (+1) cancels against join (-1): the paper's metric is
        // conservative on diamonds (the transient T1 || T2 parallelism
        // is still exploited by the adaptive engine mode).
        assert_eq!(a.doa_dep, 0);
        // Join node merges into the lower branch.
        assert_eq!(a.branches.branch_of[3], a.branches.branch_of[1]);
        // Membership still distinguishes the two diverging segments.
        assert_eq!(a.branches.count(), 2);
    }

    #[test]
    fn critical_path_chain_is_sum() {
        let d = figures::chain(4);
        let a = DagAnalysis::of(&d);
        let cp = a.critical_path(&d, &[1.0, 2.0, 3.0, 4.0]);
        assert!((cp - 10.0).abs() < 1e-12);
    }

    #[test]
    fn critical_path_fig2b_worked_example() {
        // §5.3: t0=500, t1=t2=1000, t3=t5=2000, t4=4000.
        // Critical path = t0 + max(1000+2000+2000, 1000+4000) = 5500.
        let d = figures::fig2b();
        let a = DagAnalysis::of(&d);
        let cp = a.critical_path(&d, &[500.0, 1000.0, 1000.0, 2000.0, 4000.0, 2000.0]);
        assert!((cp - 5500.0).abs() < 1e-12, "cp={cp}");
    }

    #[test]
    fn rank_groups_partition_nodes() {
        let d = figures::fig2c();
        let a = DagAnalysis::of(&d);
        let total: usize = a.rank_groups().iter().map(|g| g.len()).sum();
        assert_eq!(total, d.len());
    }

    /// Property: on random forests (trees built by random parent
    /// choice), branches == leaves, so DOA_dep == leaves - 1.
    #[test]
    fn property_tree_branches_equal_leaves() {
        check_bool(
            0x7EE5,
            300,
            |rng: &mut Rng, size| {
                let n = 2 + size.0;
                // parent[i] < i for i>=1 -> a random tree.
                (1..n).map(|i| rng.below(i as u64) as usize).collect::<Vec<_>>()
            },
            |parents| {
                let n = parents.len() + 1;
                let mut d = Dag::new();
                for i in 0..n {
                    d.add_node(format!("T{i}"));
                }
                for (i, &p) in parents.iter().enumerate() {
                    d.add_edge(p, i + 1).unwrap();
                }
                let a = DagAnalysis::of(&d);
                a.branches.count() == d.leaves().len()
                    && a.doa_dep == d.leaves().len() - 1
            },
        );
    }

    /// Property: DOA_dep is invariant to adding a chain prefix.
    #[test]
    fn property_chain_prefix_preserves_doa() {
        check_bool(
            0xC0DE,
            100,
            |rng: &mut Rng, size| {
                let fanout = 1 + rng.below(1 + size.0 as u64) as usize;
                let prefix = 1 + rng.below(4) as usize;
                (prefix, fanout)
            },
            |&(prefix, fanout)| {
                // chain of `prefix` then fork into `fanout` leaves.
                let mut d = Dag::new();
                for i in 0..prefix + fanout {
                    d.add_node(format!("T{i}"));
                }
                for i in 1..prefix {
                    d.add_edge(i - 1, i).unwrap();
                }
                for f in 0..fanout {
                    d.add_edge(prefix - 1, prefix + f).unwrap();
                }
                DagAnalysis::of(&d).doa_dep == fanout - 1
            },
        );
    }
}
