//! The DAG container: adjacency lists, validation, topological order,
//! Graphviz export.

use std::collections::BTreeSet;

use crate::error::{Error, Result};
use crate::util::json::{obj, FromJson, Json, ToJson};

/// A directed acyclic graph over task-set nodes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Dag {
    names: Vec<String>,
    /// `children[v]` = nodes depending on v.
    children: Vec<Vec<usize>>,
    /// `parents[v]` = dependencies of v.
    parents: Vec<Vec<usize>>,
}

impl Dag {
    pub fn new() -> Dag {
        Dag::default()
    }

    pub fn add_node(&mut self, name: impl Into<String>) -> usize {
        self.names.push(name.into());
        self.children.push(vec![]);
        self.parents.push(vec![]);
        self.names.len() - 1
    }

    /// Add edge `from -> to` (to depends on from). Rejects self-loops,
    /// unknown nodes, duplicate edges, and edges that would close a cycle.
    pub fn add_edge(&mut self, from: usize, to: usize) -> Result<()> {
        let n = self.len();
        if from >= n || to >= n {
            return Err(Error::InvalidDag(format!(
                "edge ({from}->{to}) references unknown node (n={n})"
            )));
        }
        if from == to {
            return Err(Error::InvalidDag(format!("self-loop on node {from}")));
        }
        if self.children[from].contains(&to) {
            return Err(Error::InvalidDag(format!("duplicate edge {from}->{to}")));
        }
        if self.reaches(to, from) {
            return Err(Error::InvalidDag(format!(
                "edge {from}->{to} would create a cycle"
            )));
        }
        self.children[from].push(to);
        self.parents[to].push(from);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn name(&self, v: usize) -> &str {
        &self.names[v]
    }

    pub fn node_by_name(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    pub fn children(&self, v: usize) -> &[usize] {
        &self.children[v]
    }

    pub fn parents(&self, v: usize) -> &[usize] {
        &self.parents[v]
    }

    pub fn out_degree(&self, v: usize) -> usize {
        self.children[v].len()
    }

    pub fn in_degree(&self, v: usize) -> usize {
        self.parents[v].len()
    }

    pub fn roots(&self) -> Vec<usize> {
        (0..self.len()).filter(|&v| self.parents[v].is_empty()).collect()
    }

    pub fn leaves(&self) -> Vec<usize> {
        (0..self.len()).filter(|&v| self.children[v].is_empty()).collect()
    }

    pub fn edge_count(&self) -> usize {
        self.children.iter().map(|c| c.len()).sum()
    }

    /// DFS reachability from `a` to `b`.
    fn reaches(&self, a: usize, b: usize) -> bool {
        let mut stack = vec![a];
        let mut seen = vec![false; self.len()];
        while let Some(v) = stack.pop() {
            if v == b {
                return true;
            }
            if std::mem::replace(&mut seen[v], true) {
                continue;
            }
            stack.extend(self.children[v].iter().copied());
        }
        false
    }

    /// Kahn topological order. Errors only on internal inconsistency
    /// (edges are cycle-checked at insertion).
    pub fn topo_order(&self) -> Result<Vec<usize>> {
        let mut indeg: Vec<usize> = (0..self.len()).map(|v| self.in_degree(v)).collect();
        let mut queue: Vec<usize> =
            (0..self.len()).filter(|&v| indeg[v] == 0).collect();
        let mut out = Vec::with_capacity(self.len());
        let mut head = 0;
        while head < queue.len() {
            let v = queue[head];
            head += 1;
            out.push(v);
            for &c in &self.children[v] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push(c);
                }
            }
        }
        if out.len() != self.len() {
            return Err(Error::InvalidDag("cycle detected in topo sort".into()));
        }
        Ok(out)
    }

    /// Weakly connected components; returns component id per node.
    pub fn components(&self) -> Vec<usize> {
        let mut comp = vec![usize::MAX; self.len()];
        let mut next = 0;
        for start in 0..self.len() {
            if comp[start] != usize::MAX {
                continue;
            }
            let mut stack = vec![start];
            comp[start] = next;
            while let Some(v) = stack.pop() {
                for &u in self.children[v].iter().chain(self.parents[v].iter()) {
                    if comp[u] == usize::MAX {
                        comp[u] = next;
                        stack.push(u);
                    }
                }
            }
            next += 1;
        }
        comp
    }

    /// All ancestors of `v` (transitive parents).
    pub fn ancestors(&self, v: usize) -> BTreeSet<usize> {
        let mut out = BTreeSet::new();
        let mut stack: Vec<usize> = self.parents[v].to_vec();
        while let Some(u) = stack.pop() {
            if out.insert(u) {
                stack.extend(self.parents[u].iter().copied());
            }
        }
        out
    }

    /// True when u and v have no dependency in either direction — the
    /// paper's condition for task-level asynchronous execution (§6.1).
    pub fn independent(&self, u: usize, v: usize) -> bool {
        u != v && !self.reaches(u, v) && !self.reaches(v, u)
    }

    /// All edges as `(from, to)` pairs, in insertion order per node.
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.edge_count());
        for (v, cs) in self.children.iter().enumerate() {
            for &c in cs {
                out.push((v, c));
            }
        }
        out
    }

    /// Graphviz dot rendering (debugging / docs).
    pub fn to_dot(&self) -> String {
        let mut s = String::from("digraph dag {\n  rankdir=TB;\n");
        for (i, name) in self.names.iter().enumerate() {
            s.push_str(&format!("  n{i} [label=\"{name}\"];\n"));
        }
        for (v, cs) in self.children.iter().enumerate() {
            for &c in cs {
                s.push_str(&format!("  n{v} -> n{c};\n"));
            }
        }
        s.push_str("}\n");
        s
    }
}

impl ToJson for Dag {
    fn to_json(&self) -> Json {
        obj([
            (
                "nodes",
                Json::Arr(self.names.iter().map(|n| Json::from(n.clone())).collect()),
            ),
            (
                "edges",
                Json::Arr(
                    self.edges()
                        .into_iter()
                        .map(|(a, b)| Json::Arr(vec![Json::from(a), Json::from(b)]))
                        .collect(),
                ),
            ),
        ])
    }
}

impl FromJson for Dag {
    fn from_json(v: &Json) -> Result<Dag> {
        let mut dag = Dag::new();
        for n in v.req_arr("nodes")? {
            let name = n
                .as_str()
                .ok_or_else(|| Error::Config("dag: node names must be strings".into()))?;
            dag.add_node(name);
        }
        for e in v.req_arr("edges")? {
            let pair = e.as_arr().filter(|p| p.len() == 2).ok_or_else(|| {
                Error::Config("dag: each edge must be a [from, to] pair".into())
            })?;
            let from = pair[0]
                .as_u64()
                .ok_or_else(|| Error::Config("dag: bad edge endpoint".into()))?;
            let to = pair[1]
                .as_u64()
                .ok_or_else(|| Error::Config("dag: bad edge endpoint".into()))?;
            // add_edge re-validates bounds, cycles and duplicates.
            dag.add_edge(from as usize, to as usize)?;
        }
        Ok(dag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_bool;
    use crate::util::rng::Rng;

    #[test]
    fn build_and_query() {
        let mut d = Dag::new();
        let a = d.add_node("A");
        let b = d.add_node("B");
        let c = d.add_node("C");
        d.add_edge(a, b).unwrap();
        d.add_edge(b, c).unwrap();
        assert_eq!(d.roots(), vec![a]);
        assert_eq!(d.leaves(), vec![c]);
        assert_eq!(d.children(a), &[b]);
        assert_eq!(d.parents(c), &[b]);
        assert_eq!(d.node_by_name("B"), Some(b));
        assert_eq!(d.edge_count(), 2);
    }

    #[test]
    fn rejects_cycles_self_loops_duplicates() {
        let mut d = Dag::new();
        let a = d.add_node("A");
        let b = d.add_node("B");
        d.add_edge(a, b).unwrap();
        assert!(d.add_edge(b, a).is_err(), "cycle");
        assert!(d.add_edge(a, a).is_err(), "self-loop");
        assert!(d.add_edge(a, b).is_err(), "duplicate");
        assert!(d.add_edge(a, 99).is_err(), "unknown node");
    }

    #[test]
    fn topo_order_respects_edges() {
        let d = crate::dag::figures::fig2c();
        let order = d.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; d.len()];
            for (i, &v) in order.iter().enumerate() {
                p[v] = i;
            }
            p
        };
        for v in 0..d.len() {
            for &c in d.children(v) {
                assert!(pos[v] < pos[c]);
            }
        }
    }

    #[test]
    fn components_and_independence() {
        let d = crate::dag::figures::edgeless(3);
        assert_eq!(d.components(), vec![0, 1, 2]);
        assert!(d.independent(0, 2));

        let c = crate::dag::figures::chain(3);
        assert_eq!(c.components(), vec![0, 0, 0]);
        assert!(!c.independent(0, 2));
        assert!(!c.independent(2, 0));
    }

    #[test]
    fn ancestors_transitive() {
        let d = crate::dag::figures::fig2b();
        let anc = d.ancestors(5);
        assert_eq!(anc.into_iter().collect::<Vec<_>>(), vec![0, 1, 3]);
    }

    #[test]
    fn dot_contains_all_nodes() {
        let d = crate::dag::figures::fig2b();
        let dot = d.to_dot();
        for i in 0..6 {
            assert!(dot.contains(&format!("T{i}")));
        }
    }

    /// Property: random DAG construction (edges only added i<j) always
    /// yields a valid topo order containing every node exactly once.
    #[test]
    fn property_random_dags_topo_sort() {
        check_bool(
            0xDA6,
            200,
            |rng: &mut Rng, size| {
                let n = 2 + size.0;
                let mut edges = vec![];
                for j in 1..n {
                    for i in 0..j {
                        if rng.f64() < 0.3 {
                            edges.push((i, j));
                        }
                    }
                }
                (n, edges)
            },
            |(n, edges)| {
                let mut d = Dag::new();
                for i in 0..*n {
                    d.add_node(format!("T{i}"));
                }
                for &(i, j) in edges {
                    d.add_edge(i, j).unwrap();
                }
                let order = d.topo_order().unwrap();
                let mut seen = vec![false; *n];
                for &v in &order {
                    for &p in d.parents(v) {
                        if !seen[p] {
                            return false;
                        }
                    }
                    seen[v] = true;
                }
                order.len() == *n
            },
        );
    }
}
