//! Dependency-graph layer (substrate S7).
//!
//! Workflows are DAGs whose nodes are *task sets* and whose edges are
//! data dependencies (§5.1). This module provides the graph type, the
//! paper's degree-of-asynchronicity analysis (DOA_dep via independent
//! branch discovery), rank (breadth-first level) computation, critical
//! paths, and Graphviz export.

mod analysis;
mod graph;

pub use analysis::{BranchDecomposition, DagAnalysis};
pub use graph::Dag;

/// The paper's Fig. 2 reference graphs, used by tests and docs.
pub mod figures {
    use super::Dag;

    /// Fig. 2a: a linear chain T0 -> T1 -> ... -> T{n-1}. DOA_dep = 0.
    pub fn chain(n: usize) -> Dag {
        let mut d = Dag::new();
        let ids: Vec<_> = (0..n).map(|i| d.add_node(format!("T{i}"))).collect();
        for w in ids.windows(2) {
            d.add_edge(w[0], w[1]).unwrap();
        }
        d
    }

    /// Fig. 2b: T0 forks into chains {T1,T3,T5} and {T2,T4}. DOA_dep = 1.
    pub fn fig2b() -> Dag {
        let mut d = Dag::new();
        let t: Vec<_> = (0..6).map(|i| d.add_node(format!("T{i}"))).collect();
        d.add_edge(t[0], t[1]).unwrap();
        d.add_edge(t[0], t[2]).unwrap();
        d.add_edge(t[1], t[3]).unwrap();
        d.add_edge(t[2], t[4]).unwrap();
        d.add_edge(t[3], t[5]).unwrap();
        d
    }

    /// Fig. 2c: ten task sets, four forks, five diverging paths.
    /// DOA_dep = 4.
    pub fn fig2c() -> Dag {
        let mut d = Dag::new();
        let t: Vec<_> = (0..10).map(|i| d.add_node(format!("T{i}"))).collect();
        d.add_edge(t[0], t[1]).unwrap();
        d.add_edge(t[0], t[2]).unwrap();
        d.add_edge(t[1], t[3]).unwrap();
        d.add_edge(t[1], t[4]).unwrap();
        d.add_edge(t[2], t[5]).unwrap();
        d.add_edge(t[2], t[6]).unwrap();
        d.add_edge(t[3], t[7]).unwrap();
        d.add_edge(t[3], t[8]).unwrap();
        d.add_edge(t[4], t[9]).unwrap();
        d
    }

    /// Fig. 2d: n+1 fully independent task sets (empty edge set).
    /// DOA_dep = n.
    pub fn edgeless(n_plus_1: usize) -> Dag {
        let mut d = Dag::new();
        for i in 0..n_plus_1 {
            d.add_node(format!("T{i}"));
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::figures::*;
    use super::*;

    // Experiment E7: the paper's Fig. 2 DOA_dep values.
    #[test]
    fn fig2_doa_values() {
        assert_eq!(DagAnalysis::of(&chain(4)).doa_dep, 0);
        assert_eq!(DagAnalysis::of(&fig2b()).doa_dep, 1);
        assert_eq!(DagAnalysis::of(&fig2c()).doa_dep, 4);
        assert_eq!(DagAnalysis::of(&edgeless(7)).doa_dep, 6);
    }

    #[test]
    fn fig2b_branches() {
        let a = DagAnalysis::of(&fig2b());
        assert_eq!(a.branches.count(), 2);
        // Branch of T1/T3/T5 differs from branch of T2/T4.
        let b = &a.branches.branch_of;
        assert_eq!(b[1], b[3]);
        assert_eq!(b[3], b[5]);
        assert_eq!(b[2], b[4]);
        assert_ne!(b[1], b[2]);
    }

    #[test]
    fn ranks_are_breadth_first() {
        let d = fig2b();
        let a = DagAnalysis::of(&d);
        assert_eq!(a.ranks, vec![0, 1, 1, 2, 2, 3]);
        assert_eq!(a.num_ranks, 4);
    }
}
