//! Checkpoint/resume of the whole simulation (substrate S18).
//!
//! HPC allocations are finite and preemptible: a Summit-class pilot
//! job hits its walltime limit, a preemptible/backfill allocation is
//! revoked, a campaign outlives its batch slot. RADICAL-Pilot's design
//! papers treat surviving allocation boundaries as a first-class
//! middleware concern; this module gives the engine that property.
//!
//! A [`SimSnapshot`] captures the **entire live simulation** at one
//! engine instant — the coordinator's pending-arrival queue, every
//! live [`WorkflowDriver`](crate::engine::WorkflowDriver)'s dependency
//! countdowns / deferred activations / task records, the reports of
//! already-finished members, the global uid slab and its free list,
//! the allocator's per-node occupancy, drain flags and first-fit
//! cursor, the scheduler queue, every in-flight task's placement, the
//! offered-capacity timeline, and the remaining
//! [`ResourcePlan`](crate::pilot::ResourcePlan) position — as
//! deterministic JSON via the crate's [`ToJson`]/[`FromJson`] spine.
//!
//! ## Semantics
//!
//! - **Checkpoint** —
//!   [`Coordinator::run_until`](crate::engine::Coordinator::run_until)
//!   stops the event loop at its top the moment the clock reaches the
//!   checkpoint time. Task completions landing *exactly* at that
//!   instant have already been drained (they are what advances the
//!   clock), while arrivals, stage activations and resizes due at it
//!   are still pending — restore re-enters the loop at exactly the
//!   iteration the uninterrupted run would have executed next.
//! - **Restore** —
//!   [`Coordinator::restore`](crate::engine::Coordinator::restore)
//!   rebuilds the loop state.
//!   In-flight tasks are re-injected into the fresh executor with
//!   their original start times and sampled durations (the snapshot
//!   carries their progress), and their placements are re-claimed on
//!   the rebuilt allocator: completions land at exactly the instants
//!   the uninterrupted run saw. The headline invariant, enforced by
//!   `tests/checkpoint.rs`: for any seed, checkpoint-at-T + resume
//!   produces reports **bit-identical** to the uninterrupted run.
//! - **Resume on a different-shaped pilot** — attach a new
//!   [`ResourcePlan`](crate::pilot::ResourcePlan) to the restored
//!   coordinator: its events are absolute engine times, so `0:-4`
//!   drains four nodes at the resume instant (gracefully — work still
//!   running on them finishes first; nothing is stranded) and the
//!   autoscaler can grow the follow-up allocation on backlog pressure.
//!
//! ## What is *not* captured
//!
//! Wall-clock scheduler accounting (`sched_wall`) restarts at zero —
//! it measures this process, not the simulation. The only live RNG
//! stream mid-run is the failure process's fault stream (TX streams
//! are keyed per set, arrival/mix streams are drawn up front, retry
//! jitter is keyed per `(seed, uid, attempt)`); its position rides in
//! the snapshot's `failure` state via
//! [`Rng::state`](crate::util::rng::Rng::state) /
//! [`from_state`](crate::util::rng::Rng::from_state), together with
//! the pending retry-backoff entries and per-task attempt counts — so
//! a resumed run replays the exact fault schedule the uninterrupted
//! one would have seen.
//!
//! ```
//! use asyncflow::engine::{Coordinator, EngineConfig, ExecutionMode, RunOutcome};
//! use asyncflow::checkpoint::SimSnapshot;
//! use asyncflow::resources::ClusterSpec;
//! use asyncflow::sim::VirtualExecutor;
//! use asyncflow::util::json::{FromJson, Json, ToJson};
//! use asyncflow::workflows::cdg2;
//!
//! let cluster = ClusterSpec::summit_8gpu();
//! let cfg = EngineConfig::default();
//! let mut coord = Coordinator::new(&cluster, &cfg);
//! coord.add_workflow(cdg2(), ExecutionMode::Asynchronous, 0.0).unwrap();
//!
//! // Preempted at t = 500 s: snapshot, serialize, (pretend to) move
//! // to a new allocation, restore, finish.
//! let mut ex = VirtualExecutor::new();
//! let RunOutcome::Checkpointed(snap) = coord.checkpoint(&mut ex, 500.0).unwrap()
//! else { panic!("cdg2 runs past 500 s") };
//! let wire = snap.to_json().to_string();
//! let snap = SimSnapshot::from_json(&Json::parse(&wire).unwrap()).unwrap();
//! let mut ex2 = VirtualExecutor::new();
//! let reports = asyncflow::engine::Coordinator::restore(snap)
//!     .unwrap()
//!     .run(&mut ex2)
//!     .unwrap();
//! assert_eq!(reports.len(), 1);
//! ```

mod snapshot;

pub use snapshot::{
    DriverEntry, FinishedMember, LiveTask, PendingMember, RunningEntry, SimSnapshot,
    SNAPSHOT_FIELDS_FINGERPRINT, SNAPSHOT_VERSION,
};
