//! The snapshot schema: plain-data structs mirroring every piece of
//! live engine-loop state, with [`ToJson`]/[`FromJson`] impls and the
//! structural validation run before a restore.

use crate::engine::{DriverState, EngineConfig, ExecutionMode};
use crate::entk::Workflow;
use crate::error::{Error, Result};
use crate::failure::{FailureState, RetryEntry};
use crate::metrics::{CapacityTimeline, TaskRecord};
use crate::pilot::{AutoscalePolicy, ResizeEvent};
use crate::resources::{ClusterSpec, NodeSpec, Placement};
use crate::sched::QueuedTask;
use crate::task::TaskSpec;
use crate::util::json::{arr_of, from_u64, obj, parse_arr, FromJson, Json, ToJson};

/// Schema version stamped into every snapshot; bumped on breaking
/// layout changes so a stale checkpoint fails loudly instead of
/// restoring garbage. (v2: queued tasks carry the owning driver slot
/// and service estimate — the fair-share and backfill policy inputs.
/// v3: failure-injection state — the fault process' RNG position and
/// pending fault, killed tasks waiting out retry backoff, and per-uid
/// attempt counts.)
pub const SNAPSHOT_VERSION: u64 = 3;

/// Fingerprint of the snapshot-struct field lists, recorded by
/// `asyncflow lint` (rule SER002): `"v{SNAPSHOT_VERSION}:{fnv1a64 of
/// the canonical field-list string, 16 hex digits}"`. Editing any
/// watched struct's fields changes the hash and fails lint until
/// SNAPSHOT_VERSION is bumped and this constant is re-recorded — the
/// lint finding prints the new expected value. Do not edit by hand
/// except to paste that value.
pub const SNAPSHOT_FIELDS_FINGERPRINT: &str = "v3:443aef07ad96b5bf";

/// A registered workflow whose driver has not materialized yet: until
/// the engine clock reaches `arrival` it costs one workflow spec, no
/// per-task state. This is also the coordinator's *internal* pending
/// representation, so snapshots carry it verbatim.
#[derive(Debug, Clone)]
pub struct PendingMember {
    pub wf: Workflow,
    pub mode: ExecutionMode,
    /// When the workflow arrives at the shared agent (engine seconds).
    pub arrival: f64,
    /// Member slot (index of its report in the run result, i.e.
    /// registration order).
    pub slot: usize,
    /// TX-stream base (cumulative set count — the merged-DAG node
    /// offset).
    pub set_stream: u64,
    /// Priority base (cumulative pipeline count).
    pub pipeline_base: u64,
}

/// A live driver's evolving state, tagged with its member slot.
#[derive(Debug, Clone)]
pub struct DriverEntry {
    pub slot: usize,
    pub state: DriverState,
}

/// A member that finished before the checkpoint: everything needed to
/// rebuild its [`RunReport`](crate::engine::RunReport) at restore.
#[derive(Debug, Clone)]
pub struct FinishedMember {
    pub slot: usize,
    pub workflow: String,
    pub mode: ExecutionMode,
    pub records: Vec<TaskRecord>,
    /// Offered-capacity timeline *as of the member's fold instant* —
    /// the report is rebuilt against it so the member's utilization
    /// trace matches the uninterrupted run exactly (a capacity change
    /// between the member's finish and the checkpoint must not leak
    /// into its trace).
    pub capacity: CapacityTimeline,
    pub failed_tasks: usize,
}

/// One live (queued or running) entry of the global uid slab.
#[derive(Debug, Clone)]
pub struct LiveTask {
    pub uid: usize,
    pub slot: usize,
    pub local: usize,
    pub spec: TaskSpec,
}

/// One in-flight task's placement (uid -> where its resources live).
#[derive(Debug, Clone)]
pub struct RunningEntry {
    pub uid: usize,
    pub placement: Placement,
}

/// Complete, self-contained state of one interrupted simulation: the
/// inverse image of the coordinator event loop at a single engine
/// instant. Serialize with [`ToJson`]; restore through
/// [`Coordinator::restore`](crate::engine::Coordinator::restore).
#[derive(Debug, Clone)]
pub struct SimSnapshot {
    /// Engine time of the checkpoint (the loop top the restore
    /// re-enters).
    pub now: f64,
    pub cfg: EngineConfig,
    /// Cluster the workflows were registered against (feasibility
    /// checks; the live node inventory is `nodes`).
    pub cluster: ClusterSpec,
    /// Total registered members (pending + live + finished).
    pub n_members: usize,
    pub next_set_stream: u64,
    pub next_pipeline: u64,
    pub pending: Vec<PendingMember>,
    pub drivers: Vec<DriverEntry>,
    pub finished: Vec<FinishedMember>,
    /// Size of the uid slab (live entries + free list).
    pub slab_len: usize,
    pub live_tasks: Vec<LiveTask>,
    /// Recycled uids, in stack order (pop order matters for exact
    /// replay of uid assignment).
    pub free_uids: Vec<usize>,
    pub peak_live: usize,
    /// Node inventory at checkpoint time (including drained slots —
    /// indices are stable for in-flight placements).
    pub nodes: Vec<NodeSpec>,
    pub draining: Vec<bool>,
    /// First-fit rotation position of the allocator.
    pub cursor: usize,
    /// The allocator's cached spanning-allocation node order when it
    /// was valid at checkpoint time (`None` = stale, rebuilt on first
    /// use). Carried because its equal-free tie-breaks are
    /// repair-history dependent.
    pub span_order: Option<Vec<usize>>,
    pub running: Vec<RunningEntry>,
    /// Scheduler queue in insertion order.
    pub queue: Vec<QueuedTask>,
    /// Non-default fair-share weights `(tenant, weight)` — replayed
    /// through the scheduler on restore so a weighted run resumes
    /// bit-identically (empty for unweighted policies).
    pub tenant_weights: Vec<(usize, f64)>,
    pub capacity: CapacityTimeline,
    /// Resize events not yet applied, in time order.
    pub resize_events: Vec<ResizeEvent>,
    pub autoscale: Option<AutoscalePolicy>,
    pub next_check: Option<f64>,
    pub stalled_checks: u32,
    pub grow_node: Option<NodeSpec>,
    pub sched_rounds: usize,
    pub sched_dirty: bool,
    /// Failure-injection process state when failure injection was
    /// active (`None` otherwise): spec, RNG position, pending fault
    /// time, trace cursor and cumulative resilience stats — the resumed
    /// fault sequence is bit-identical to the uninterrupted one.
    pub failure: Option<FailureState>,
    /// Killed tasks waiting out their retry backoff. Their uids are
    /// *live* (spec and route survive the backoff) but neither running
    /// nor queued.
    pub retries: Vec<RetryEntry>,
    /// Sparse per-uid attempt counts: `(uid, times killed)` for every
    /// uid with a nonzero count.
    pub attempts: Vec<(usize, u32)>,
}

fn usize_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::from(x)).collect())
}

fn parse_usize_arr(v: &Json, key: &str) -> Result<Vec<usize>> {
    parse_usize_arr_value(v.get(key), key)
}

fn parse_usize_arr_value(v: &Json, what: &str) -> Result<Vec<usize>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| Error::Config(format!("snapshot: '{what}' must be an array")))?;
    let mut out = Vec::with_capacity(arr.len());
    for x in arr {
        out.push(x.as_u64().ok_or_else(|| {
            Error::Config(format!("snapshot: bad index in '{what}'"))
        })? as usize);
    }
    Ok(out)
}

fn mode_from(v: &Json, key: &str) -> Result<ExecutionMode> {
    v.req_str(key)?.parse()
}

impl ToJson for DriverState {
    fn to_json(&self) -> Json {
        obj([
            ("wf", self.wf.to_json()),
            ("mode", Json::from(self.mode.label())),
            ("arrival", Json::from(self.arrival)),
            ("set_stream_offset", from_u64(self.set_stream_offset)),
            ("pipeline_offset", from_u64(self.pipeline_offset)),
            ("deps_left", usize_arr(&self.deps_left)),
            ("tasks_left", usize_arr(&self.tasks_left)),
            ("jobset_of", usize_arr(&self.jobset_of)),
            ("records", arr_of(&self.records)),
            (
                "deferred",
                Json::Arr(
                    self.deferred
                        .iter()
                        .map(|&(t, js)| Json::Arr(vec![Json::from(t), Json::from(js)]))
                        .collect(),
                ),
            ),
            ("tasks_remaining", from_u64(self.tasks_remaining)),
            ("failed_tasks", Json::from(self.failed_tasks)),
        ])
    }
}

impl FromJson for DriverState {
    fn from_json(v: &Json) -> Result<DriverState> {
        let records: Vec<TaskRecord> = parse_arr(v, "records")?;
        let mut deferred = Vec::new();
        for d in v.req_arr("deferred")? {
            let pair = d.as_arr().filter(|p| p.len() == 2).ok_or_else(|| {
                Error::Config("snapshot: deferred entries must be [time, jobset]".into())
            })?;
            let t = pair[0]
                .as_f64()
                .ok_or_else(|| Error::Config("snapshot: bad deferred time".into()))?;
            let js = pair[1]
                .as_u64()
                .ok_or_else(|| Error::Config("snapshot: bad deferred jobset".into()))?;
            deferred.push((t, js as usize));
        }
        Ok(DriverState {
            wf: Workflow::from_json(v.get("wf"))?,
            mode: mode_from(v, "mode")?,
            arrival: v.req_f64("arrival")?,
            set_stream_offset: v.req_u64("set_stream_offset")?,
            pipeline_offset: v.req_u64("pipeline_offset")?,
            deps_left: parse_usize_arr(v, "deps_left")?,
            tasks_left: parse_usize_arr(v, "tasks_left")?,
            jobset_of: parse_usize_arr(v, "jobset_of")?,
            records,
            deferred,
            tasks_remaining: v.req_u64("tasks_remaining")?,
            failed_tasks: v.req_u64("failed_tasks")? as usize,
        })
    }
}

impl ToJson for PendingMember {
    fn to_json(&self) -> Json {
        obj([
            ("wf", self.wf.to_json()),
            ("mode", Json::from(self.mode.label())),
            ("arrival", Json::from(self.arrival)),
            ("slot", Json::from(self.slot)),
            ("set_stream", from_u64(self.set_stream)),
            ("pipeline_base", from_u64(self.pipeline_base)),
        ])
    }
}

impl FromJson for PendingMember {
    fn from_json(v: &Json) -> Result<PendingMember> {
        Ok(PendingMember {
            wf: Workflow::from_json(v.get("wf"))?,
            mode: mode_from(v, "mode")?,
            arrival: v.req_f64("arrival")?,
            slot: v.req_u64("slot")? as usize,
            set_stream: v.req_u64("set_stream")?,
            pipeline_base: v.req_u64("pipeline_base")?,
        })
    }
}

impl ToJson for DriverEntry {
    fn to_json(&self) -> Json {
        obj([("slot", Json::from(self.slot)), ("state", self.state.to_json())])
    }
}

impl FromJson for DriverEntry {
    fn from_json(v: &Json) -> Result<DriverEntry> {
        Ok(DriverEntry {
            slot: v.req_u64("slot")? as usize,
            state: DriverState::from_json(v.get("state"))?,
        })
    }
}

impl ToJson for FinishedMember {
    fn to_json(&self) -> Json {
        obj([
            ("slot", Json::from(self.slot)),
            ("workflow", Json::from(self.workflow.clone())),
            ("mode", Json::from(self.mode.label())),
            ("records", arr_of(&self.records)),
            ("capacity", self.capacity.to_json()),
            ("failed_tasks", Json::from(self.failed_tasks)),
        ])
    }
}

impl FromJson for FinishedMember {
    fn from_json(v: &Json) -> Result<FinishedMember> {
        Ok(FinishedMember {
            slot: v.req_u64("slot")? as usize,
            workflow: v.req_str("workflow")?.to_string(),
            mode: mode_from(v, "mode")?,
            records: parse_arr(v, "records")?,
            capacity: CapacityTimeline::from_json(v.get("capacity"))?,
            failed_tasks: v.req_u64("failed_tasks")? as usize,
        })
    }
}

impl ToJson for LiveTask {
    fn to_json(&self) -> Json {
        obj([
            ("uid", Json::from(self.uid)),
            ("slot", Json::from(self.slot)),
            ("local", Json::from(self.local)),
            ("spec", self.spec.to_json()),
        ])
    }
}

impl FromJson for LiveTask {
    fn from_json(v: &Json) -> Result<LiveTask> {
        Ok(LiveTask {
            uid: v.req_u64("uid")? as usize,
            slot: v.req_u64("slot")? as usize,
            local: v.req_u64("local")? as usize,
            spec: TaskSpec::from_json(v.get("spec"))?,
        })
    }
}

impl ToJson for RunningEntry {
    fn to_json(&self) -> Json {
        obj([("uid", Json::from(self.uid)), ("placement", self.placement.to_json())])
    }
}

impl FromJson for RunningEntry {
    fn from_json(v: &Json) -> Result<RunningEntry> {
        Ok(RunningEntry {
            uid: v.req_u64("uid")? as usize,
            placement: Placement::from_json(v.get("placement"))?,
        })
    }
}

impl ToJson for SimSnapshot {
    fn to_json(&self) -> Json {
        obj([
            ("version", from_u64(SNAPSHOT_VERSION)),
            ("now", Json::from(self.now)),
            ("cfg", self.cfg.to_json()),
            ("cluster", self.cluster.to_json()),
            ("n_members", Json::from(self.n_members)),
            ("next_set_stream", from_u64(self.next_set_stream)),
            ("next_pipeline", from_u64(self.next_pipeline)),
            ("pending", arr_of(&self.pending)),
            ("drivers", arr_of(&self.drivers)),
            ("finished", arr_of(&self.finished)),
            ("slab_len", Json::from(self.slab_len)),
            ("live_tasks", arr_of(&self.live_tasks)),
            ("free_uids", usize_arr(&self.free_uids)),
            ("peak_live", Json::from(self.peak_live)),
            ("nodes", arr_of(&self.nodes)),
            (
                "draining",
                Json::Arr(self.draining.iter().map(|&d| Json::from(d)).collect()),
            ),
            ("cursor", Json::from(self.cursor)),
            (
                "span_order",
                match &self.span_order {
                    Some(o) => usize_arr(o),
                    None => Json::Null,
                },
            ),
            ("running", arr_of(&self.running)),
            ("queue", arr_of(&self.queue)),
            (
                "tenant_weights",
                Json::Arr(
                    self.tenant_weights
                        .iter()
                        .map(|&(t, w)| Json::Arr(vec![Json::from(t), Json::from(w)]))
                        .collect(),
                ),
            ),
            ("capacity", self.capacity.to_json()),
            ("resize_events", arr_of(&self.resize_events)),
            (
                "autoscale",
                match &self.autoscale {
                    Some(p) => p.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "next_check",
                match self.next_check {
                    Some(t) => Json::from(t),
                    None => Json::Null,
                },
            ),
            ("stalled_checks", Json::from(self.stalled_checks as usize)),
            (
                "grow_node",
                match &self.grow_node {
                    Some(n) => n.to_json(),
                    None => Json::Null,
                },
            ),
            ("sched_rounds", Json::from(self.sched_rounds)),
            ("sched_dirty", Json::from(self.sched_dirty)),
            (
                "failure",
                match &self.failure {
                    Some(f) => f.to_json(),
                    None => Json::Null,
                },
            ),
            ("retries", arr_of(&self.retries)),
            (
                "attempts",
                Json::Arr(
                    self.attempts
                        .iter()
                        .map(|&(uid, n)| {
                            Json::Arr(vec![Json::from(uid), Json::from(n as usize)])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl FromJson for SimSnapshot {
    fn from_json(v: &Json) -> Result<SimSnapshot> {
        let version = v.req_u64("version")?;
        if version != SNAPSHOT_VERSION {
            return Err(Error::Config(format!(
                "snapshot: version {version} is not supported (expected {SNAPSHOT_VERSION})"
            )));
        }
        let mut draining = Vec::new();
        for d in v.req_arr("draining")? {
            draining.push(d.as_bool().ok_or_else(|| {
                Error::Config("snapshot: draining flags must be booleans".into())
            })?);
        }
        let snapshot = SimSnapshot {
            now: v.req_f64("now")?,
            cfg: EngineConfig::from_json(v.get("cfg"))?,
            cluster: ClusterSpec::from_json(v.get("cluster"))?,
            n_members: v.req_u64("n_members")? as usize,
            next_set_stream: v.req_u64("next_set_stream")?,
            next_pipeline: v.req_u64("next_pipeline")?,
            pending: parse_arr(v, "pending")?,
            drivers: parse_arr(v, "drivers")?,
            finished: parse_arr(v, "finished")?,
            slab_len: v.req_u64("slab_len")? as usize,
            live_tasks: parse_arr(v, "live_tasks")?,
            free_uids: parse_usize_arr(v, "free_uids")?,
            peak_live: v.req_u64("peak_live")? as usize,
            nodes: parse_arr(v, "nodes")?,
            draining,
            cursor: v.req_u64("cursor")? as usize,
            span_order: match v.get("span_order") {
                Json::Null => None,
                o => Some(parse_usize_arr_value(o, "span_order")?),
            },
            running: parse_arr(v, "running")?,
            queue: parse_arr(v, "queue")?,
            tenant_weights: {
                let mut out = Vec::new();
                for p in v.req_arr("tenant_weights")? {
                    let pair = p.as_arr().filter(|x| x.len() == 2).ok_or_else(|| {
                        Error::Config(
                            "snapshot: tenant_weights entries must be [tenant, weight]".into(),
                        )
                    })?;
                    let t = pair[0].as_u64().ok_or_else(|| {
                        Error::Config("snapshot: bad tenant in tenant_weights".into())
                    })?;
                    let w = pair[1].as_f64().ok_or_else(|| {
                        Error::Config("snapshot: bad weight in tenant_weights".into())
                    })?;
                    out.push((t as usize, w));
                }
                out
            },
            capacity: CapacityTimeline::from_json(v.get("capacity"))?,
            resize_events: parse_arr(v, "resize_events")?,
            autoscale: match v.get("autoscale") {
                Json::Null => None,
                p => Some(AutoscalePolicy::from_json(p)?),
            },
            next_check: match v.get("next_check") {
                Json::Null => None,
                t => Some(t.as_f64().ok_or_else(|| {
                    Error::Config("snapshot: next_check must be a number or null".into())
                })?),
            },
            stalled_checks: v.req_u64("stalled_checks")? as u32,
            grow_node: match v.get("grow_node") {
                Json::Null => None,
                n => Some(NodeSpec::from_json(n)?),
            },
            sched_rounds: v.req_u64("sched_rounds")? as usize,
            sched_dirty: v.req_bool("sched_dirty")?,
            failure: match v.get("failure") {
                Json::Null => None,
                f => Some(FailureState::from_json(f)?),
            },
            retries: parse_arr(v, "retries")?,
            attempts: {
                let mut out = Vec::new();
                for p in v.req_arr("attempts")? {
                    let pair = p.as_arr().filter(|x| x.len() == 2).ok_or_else(|| {
                        Error::Config(
                            "snapshot: attempts entries must be [uid, count]".into(),
                        )
                    })?;
                    let uid = pair[0].as_u64().ok_or_else(|| {
                        Error::Config("snapshot: bad uid in attempts".into())
                    })?;
                    let n = pair[1].as_u64().ok_or_else(|| {
                        Error::Config("snapshot: bad count in attempts".into())
                    })?;
                    out.push((uid as usize, n as u32));
                }
                out
            },
        };
        snapshot.validate()?;
        Ok(snapshot)
    }
}

impl SimSnapshot {
    /// Structural consistency checks run before any restore: slot and
    /// uid spaces must partition cleanly, every running/queued uid must
    /// be live, and the node inventory must be internally consistent.
    /// Deeper semantic checks (placements fitting their nodes, driver
    /// countdowns matching the recompiled plan) happen while the
    /// restore rebuilds the respective component.
    pub fn validate(&self) -> Result<()> {
        if !self.now.is_finite() || self.now < 0.0 {
            return Err(Error::Config(format!(
                "snapshot: invalid checkpoint time {}",
                self.now
            )));
        }
        // Member slots: pending + live + finished partition a subset of
        // 0..n_members with no slot claimed twice.
        let mut slot_seen = vec![false; self.n_members];
        let mut claim_slot = |slot: usize, what: &str| -> Result<()> {
            if slot >= self.n_members {
                return Err(Error::Config(format!(
                    "snapshot: {what} slot {slot} out of range (n_members {})",
                    self.n_members
                )));
            }
            if std::mem::replace(&mut slot_seen[slot], true) {
                return Err(Error::Config(format!(
                    "snapshot: member slot {slot} appears twice"
                )));
            }
            Ok(())
        };
        for p in &self.pending {
            claim_slot(p.slot, "pending")?;
        }
        for d in &self.drivers {
            claim_slot(d.slot, "driver")?;
        }
        for f in &self.finished {
            claim_slot(f.slot, "finished")?;
        }
        if slot_seen.iter().any(|&s| !s) {
            return Err(Error::Config(
                "snapshot: some member slots have no pending/live/finished entry".into(),
            ));
        }
        // Uid slab: live + free partition 0..slab_len exactly.
        let mut uid_live = vec![false; self.slab_len];
        for lt in &self.live_tasks {
            if lt.uid >= self.slab_len {
                return Err(Error::Config(format!(
                    "snapshot: live uid {} out of range (slab {})",
                    lt.uid, self.slab_len
                )));
            }
            if std::mem::replace(&mut uid_live[lt.uid], true) {
                return Err(Error::Config(format!(
                    "snapshot: live uid {} appears twice",
                    lt.uid
                )));
            }
        }
        let mut uid_free = vec![false; self.slab_len];
        for &uid in &self.free_uids {
            if uid >= self.slab_len || uid_live[uid] {
                return Err(Error::Config(format!(
                    "snapshot: free uid {uid} is out of range or live"
                )));
            }
            if std::mem::replace(&mut uid_free[uid], true) {
                return Err(Error::Config(format!(
                    "snapshot: free uid {uid} appears twice"
                )));
            }
        }
        if self.live_tasks.len() + self.free_uids.len() != self.slab_len {
            return Err(Error::Config(format!(
                "snapshot: {} live + {} free uids do not cover the slab of {}",
                self.live_tasks.len(),
                self.free_uids.len(),
                self.slab_len
            )));
        }
        // Running + queued + retry-pending must partition the live
        // uids: a killed task's uid stays live across its backoff even
        // though it is neither placed nor queued.
        let mut uid_placed = vec![false; self.slab_len];
        for r in &self.running {
            if r.uid >= self.slab_len || !uid_live[r.uid] {
                return Err(Error::Config(format!(
                    "snapshot: running uid {} is not live",
                    r.uid
                )));
            }
            if std::mem::replace(&mut uid_placed[r.uid], true) {
                return Err(Error::Config(format!(
                    "snapshot: running uid {} appears twice",
                    r.uid
                )));
            }
        }
        for q in &self.queue {
            if q.uid >= self.slab_len || !uid_live[q.uid] {
                return Err(Error::Config(format!(
                    "snapshot: queued uid {} is not live",
                    q.uid
                )));
            }
            if std::mem::replace(&mut uid_placed[q.uid], true) {
                return Err(Error::Config(format!(
                    "snapshot: uid {} is both running and queued",
                    q.uid
                )));
            }
        }
        for r in &self.retries {
            if r.uid >= self.slab_len || !uid_live[r.uid] {
                return Err(Error::Config(format!(
                    "snapshot: retry-pending uid {} is not live",
                    r.uid
                )));
            }
            if std::mem::replace(&mut uid_placed[r.uid], true) {
                return Err(Error::Config(format!(
                    "snapshot: retry-pending uid {} is also running/queued",
                    r.uid
                )));
            }
            if !r.due.is_finite() || r.due < 0.0 {
                return Err(Error::Config(format!(
                    "snapshot: retry-pending uid {} has invalid due time {}",
                    r.uid, r.due
                )));
            }
        }
        if self.running.len() + self.queue.len() + self.retries.len()
            != self.live_tasks.len()
        {
            return Err(Error::Config(format!(
                "snapshot: {} running + {} queued + {} retry-pending does not \
                 match {} live tasks",
                self.running.len(),
                self.queue.len(),
                self.retries.len(),
                self.live_tasks.len()
            )));
        }
        if !self.retries.is_empty() && self.failure.is_none() {
            return Err(Error::Config(
                "snapshot: retry-pending tasks without a failure process".into(),
            ));
        }
        let mut attempt_seen = vec![false; self.slab_len];
        for &(uid, n) in &self.attempts {
            if uid >= self.slab_len {
                return Err(Error::Config(format!(
                    "snapshot: attempt count for uid {uid} outside the slab"
                )));
            }
            if n == 0 {
                return Err(Error::Config(format!(
                    "snapshot: zero attempt count for uid {uid} (sparse form \
                     carries only nonzero counts)"
                )));
            }
            if std::mem::replace(&mut attempt_seen[uid], true) {
                return Err(Error::Config(format!(
                    "snapshot: attempt count for uid {uid} appears twice"
                )));
            }
        }
        // Live tasks must route into live drivers.
        let driver_slots: std::collections::BTreeSet<usize> =
            self.drivers.iter().map(|d| d.slot).collect();
        for lt in &self.live_tasks {
            if !driver_slots.contains(&lt.slot) {
                return Err(Error::Config(format!(
                    "snapshot: live uid {} routes to slot {} with no live driver",
                    lt.uid, lt.slot
                )));
            }
        }
        // Node inventory.
        if self.draining.len() != self.nodes.len() {
            return Err(Error::Config(format!(
                "snapshot: {} drain flags for {} nodes",
                self.draining.len(),
                self.nodes.len()
            )));
        }
        if self.capacity.points.is_empty() {
            return Err(Error::Config("snapshot: empty capacity timeline".into()));
        }
        // Anything that can grow needs a node shape to grow by — the
        // event loop relies on this (a fresh run validates it when the
        // plan is attached; a corrupted snapshot must not panic there).
        if self.grow_node.is_none()
            && (self.autoscale.is_some()
                || self.resize_events.iter().any(|e| e.delta > 0))
        {
            return Err(Error::Config(
                "snapshot: growing resize events or an autoscaler without a \
                 grow-node shape"
                    .into(),
            ));
        }
        if self.next_check.is_some() && self.autoscale.is_none() {
            return Err(Error::Config(
                "snapshot: an autoscaler evaluation time without an autoscaler".into(),
            ));
        }
        Ok(())
    }
}
