//! Checkpoint-cadence layer: periodic snapshot chaining and the
//! cadence-vs-failure-rate sweep.
//!
//! Two tools share this module:
//!
//! - [`run_chained`] — the *real* thing: run a traffic scenario under
//!   `--checkpoint-every T`, snapshotting the whole simulation at every
//!   cadence multiple and resuming it from the serialized form. Every
//!   leg crosses the JSON wire format, so one chained run exercises the
//!   snapshot schema as hard as `T/makespan` separate crash/resume
//!   tests — and must still produce the bit-identical final report.
//!
//! - [`sweep_cadence`] — the *model*: for each candidate cadence,
//!   the expected wall-clock of a run of `work` seconds under an
//!   exponential fault process (rate λ, checkpoint cost C), using the
//!   classic renewal argument behind the Young/Daly optimum: a segment
//!   needing `u` uninterrupted seconds costs `(e^{λu} − 1)/λ` in
//!   expectation, so short cadences drown in checkpoint overhead and
//!   long ones in lost rework, with the minimum near
//!   `T* = sqrt(2·C·MTBF)`. A seeded fault-walk (one sampled path per
//!   cadence, same fault sequence for every cadence) rides along so the
//!   table shows a concrete draw next to the expectation — bit-identical
//!   for a given seed.

use crate::engine::{EngineConfig, EPS};
use crate::error::{Error, Result};
use crate::resources::ClusterSpec;
use crate::traffic::{
    run_traffic_resumable_obs, Catalog, TrafficCheckpoint, TrafficObs, TrafficOutcome,
    TrafficReport, TrafficSpec,
};
use crate::util::json::{obj, FromJson, Json, ToJson};
use crate::util::rng::Rng;

use super::FailureSpec;

/// Stream tag for the cadence-sweep fault walk (`"CADE"`).
const CADENCE_TAG: u64 = 0x4341_4445;

/// Sampled-path safety valve: a cadence whose segments essentially
/// never fit between faults would walk forever; past this many faults
/// the sampled path is reported as unbounded.
const MAX_WALK_FAULTS: u64 = 100_000;

/// Superposed stochastic fault rate (failures/second) the spec induces
/// on a cluster: `1/mtbf` per schedulable node, GPU nodes scaled by
/// [`FailureSpec::gpu_factor`]. Zero when the spec has no MTBF process.
pub fn cluster_fault_rate(cluster: &ClusterSpec, spec: &FailureSpec) -> f64 {
    let Some(mtbf) = spec.mtbf else { return 0.0 };
    cluster
        .nodes
        .iter()
        .map(|n| (1.0 / mtbf) * if n.gpus > 0 { spec.gpu_factor } else { 1.0 })
        .sum()
}

/// Young/Daly first-order optimal checkpoint interval
/// `T* = sqrt(2·C·MTBF)` for checkpoint cost `cost` and *system* mean
/// time between failures `1/rate`.
pub fn young_daly(cost: f64, rate: f64) -> f64 {
    if rate > 0.0 {
        (2.0 * cost / rate).sqrt()
    } else {
        f64::INFINITY
    }
}

/// One cadence's outcome in a [`CadenceSweep`]: the expectation model
/// and the sampled fault-walk, side by side.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CadencePoint {
    /// Checkpoint interval (engine seconds of committed work).
    pub cadence: f64,
    /// Expected wall-clock to finish the work (the ranking metric).
    pub expected_wall: f64,
    /// Expected fault count over the run.
    pub expected_faults: f64,
    /// Expected wall-clock lost to rework (progress destroyed by
    /// faults, checkpoint-write time of failed attempts included).
    pub expected_lost: f64,
    /// Deterministic checkpoint-write overhead: one write per
    /// completed segment except the last.
    pub checkpoint_overhead: f64,
    /// Wall-clock of the seeded sampled path (`inf` if the walk hit
    /// the fault cap without finishing).
    pub walk_wall: f64,
    /// Faults the sampled path absorbed.
    pub walk_faults: u64,
    /// Rework the sampled path lost.
    pub walk_lost: f64,
}

/// Result of [`sweep_cadence`]: per-cadence costs plus the located
/// optimum and the Young/Daly reference.
#[derive(Debug, Clone, PartialEq)]
pub struct CadenceSweep {
    /// Uninterrupted work being protected (seconds).
    pub work: f64,
    /// System fault rate λ (failures/second).
    pub rate: f64,
    /// Checkpoint write cost (seconds).
    pub cost: f64,
    /// Per-cadence outcomes, in input order.
    pub points: Vec<CadencePoint>,
    /// Index into [`points`](Self::points) of the minimal expected
    /// wall-clock (`None` if every cadence diverged).
    pub best: Option<usize>,
    /// Young/Daly `T* = sqrt(2·C/λ)` reference interval.
    pub young_daly: f64,
}

/// Lazily-extended cumulative fault times of one seeded exponential
/// process: the *same* sequence is replayed against every cadence, so
/// differences between cadences come from the cadence alone.
#[derive(Debug)]
pub struct FaultWalk {
    times: Vec<f64>,
    rng: Rng,
    rate: f64,
}

impl FaultWalk {
    /// Walk for fault rate `rate` (> 0), forked from `seed` on a
    /// dedicated stream tag.
    pub fn new(rate: f64, seed: u64) -> Result<FaultWalk> {
        if !rate.is_finite() || rate <= 0.0 {
            return Err(Error::Config(format!(
                "cadence sweep: fault rate must be positive and finite, got {rate}"
            )));
        }
        Ok(FaultWalk { times: Vec::new(), rng: Rng::new(seed).fork(CADENCE_TAG), rate })
    }

    /// Absolute time of the `i`-th fault (0-based), drawing further
    /// inter-arrival gaps on demand.
    pub fn time(&mut self, i: usize) -> f64 {
        while self.times.len() <= i {
            let prev = self.times.last().copied().unwrap_or(0.0);
            self.times.push(prev + self.rng.exp(self.rate));
        }
        self.times[i]
    }
}

/// Sweep checkpoint cadences against an exponential fault process.
///
/// `work` is the uninterrupted wall-clock being protected (typically a
/// failure-free traffic run's makespan), `rate` the system fault rate
/// (see [`cluster_fault_rate`]), `cost` the checkpoint write cost.
/// Each candidate cadence is scored by its expected wall-clock under
/// the renewal model (deterministic) and walked once against a seeded
/// fault sequence shared across cadences (bit-identical per seed).
pub fn sweep_cadence(
    work: f64,
    rate: f64,
    cost: f64,
    cadences: &[f64],
    seed: u64,
) -> Result<CadenceSweep> {
    if !work.is_finite() || work <= 0.0 {
        return Err(Error::Config(format!(
            "cadence sweep: work must be positive and finite, got {work}"
        )));
    }
    if !cost.is_finite() || cost < 0.0 {
        return Err(Error::Config(format!(
            "cadence sweep: checkpoint cost must be finite and >= 0, got {cost}"
        )));
    }
    if cadences.is_empty() {
        return Err(Error::Config("cadence sweep: no cadences given".into()));
    }
    for &t in cadences {
        if !t.is_finite() || t <= 0.0 {
            return Err(Error::Config(format!(
                "cadence sweep: cadences must be positive and finite, got {t}"
            )));
        }
    }
    let mut walk = FaultWalk::new(rate, seed)?;
    let mut points = Vec::with_capacity(cadences.len());
    for &cadence in cadences {
        points.push(score_cadence(work, rate, cost, cadence, &mut walk));
    }
    let mut best: Option<usize> = None;
    for (i, p) in points.iter().enumerate() {
        if p.expected_wall.is_finite()
            && best.is_none_or(|b| p.expected_wall < points[b].expected_wall)
        {
            best = Some(i);
        }
    }
    Ok(CadenceSweep { work, rate, cost, points, best, young_daly: young_daly(cost, rate) })
}

/// Score one cadence: closed-form expectation plus one sampled path.
fn score_cadence(
    work: f64,
    rate: f64,
    cost: f64,
    cadence: f64,
    walk: &mut FaultWalk,
) -> CadencePoint {
    // Segment layout: full `cadence`-sized segments, a (possibly
    // shorter) tail, a checkpoint write after every segment but the
    // last. `u` below is the uninterrupted time a segment needs.
    let full = (work / cadence).floor() as u64;
    let tail = work - full as f64 * cadence;
    let n_segments = full + u64::from(tail > 0.0);
    let checkpoint_overhead = n_segments.saturating_sub(1) as f64 * cost;

    // Expectation: a run needing `u` uninterrupted seconds under
    // exponential faults takes (e^{λu} − 1)/λ expected seconds and
    // absorbs e^{λu} − 1 expected faults (renewal argument).
    let mut expected_wall = 0.0;
    let mut expected_faults = 0.0;
    let mut expected_lost = 0.0;
    // Sampled path: replay the shared fault sequence, rewinding to the
    // last checkpoint on every hit.
    let mut walk_wall = 0.0;
    let mut walk_faults = 0u64;
    let mut walk_lost = 0.0;
    let mut committed = 0.0;
    let mut fault_idx = 0usize;
    for seg in 0..n_segments {
        let seg_work = if seg + 1 == n_segments && tail > 0.0 { tail } else { cadence };
        let u = seg_work + if seg + 1 == n_segments { 0.0 } else { cost };
        let e_faults = (rate * u).exp() - 1.0;
        expected_faults += e_faults;
        expected_wall += if rate > 0.0 { e_faults / rate } else { u };
        expected_lost += if rate > 0.0 { e_faults / rate - u } else { 0.0 };

        if walk_wall.is_finite() {
            loop {
                let fault_at = walk.time(fault_idx);
                if fault_at >= walk_wall + u {
                    // The segment (and its checkpoint write) fits
                    // before the next fault: commit and move on.
                    walk_wall += u;
                    committed += seg_work;
                    break;
                }
                // Fault mid-attempt: everything since the last
                // checkpoint is rework. Fail-stop-restart, no extra
                // recovery cost (matching the engine's kill model).
                walk_faults += 1;
                fault_idx += 1;
                walk_lost += fault_at - walk_wall;
                walk_wall = fault_at;
                if walk_faults >= MAX_WALK_FAULTS {
                    walk_wall = f64::INFINITY;
                    break;
                }
            }
        }
    }
    // `committed` is only consumed by the debug invariant below; the
    // name keeps the walk readable.
    debug_assert!(!walk_wall.is_finite() || (committed - work).abs() < EPS.max(work * EPS));
    CadencePoint {
        cadence,
        expected_wall,
        expected_faults,
        expected_lost,
        checkpoint_overhead,
        walk_wall,
        walk_faults,
        walk_lost,
    }
}

impl CadenceSweep {
    /// Human-readable sweep table plus the located optimum and the
    /// Young/Daly reference.
    pub fn render(&self) -> String {
        let mtbf = if self.rate > 0.0 { 1.0 / self.rate } else { f64::INFINITY };
        let mut s = format!(
            "cadence sweep: work {:.0} s, checkpoint cost {:.1} s, system MTBF {:.0} s (rate {:.3e}/s)\n",
            self.work, self.cost, mtbf, self.rate,
        );
        s.push_str(&format!(
            "{:>10} {:>13} {:>10} {:>10} {:>10} {:>12} {:>7} {:>10}\n",
            "cadence_s", "expected_wall", "e_faults", "e_lost", "ckpt_ovh", "walk_wall", "faults", "walk_lost",
        ));
        for (i, p) in self.points.iter().enumerate() {
            s.push_str(&format!(
                "{:>10.1} {:>13.1} {:>10.2} {:>10.1} {:>10.1} {:>12.1} {:>7} {:>10.1}{}\n",
                p.cadence,
                p.expected_wall,
                p.expected_faults,
                p.expected_lost,
                p.checkpoint_overhead,
                p.walk_wall,
                p.walk_faults,
                p.walk_lost,
                if Some(i) == self.best { "  <- optimal" } else { "" },
            ));
        }
        match self.best {
            Some(b) => s.push_str(&format!(
                "optimal cadence {:.1} s (expected wall {:.1} s, {:.2}x the failure-free run); Young/Daly T* = sqrt(2*C*MTBF) = {:.1} s\n",
                self.points[b].cadence,
                self.points[b].expected_wall,
                self.points[b].expected_wall / self.work,
                self.young_daly,
            )),
            None => s.push_str(
                "no cadence makes progress under this failure rate (expected wall diverged)\n",
            ),
        }
        s
    }

    /// CSV rendering: one row per cadence, `optimal` marking the
    /// minimum-expected-wall row.
    pub fn csv(&self) -> String {
        let mut s = String::from(
            "cadence_s,expected_wall_s,expected_faults,expected_lost_s,\
             checkpoint_overhead_s,walk_wall_s,walk_faults,walk_lost_s,optimal\n",
        );
        for (i, p) in self.points.iter().enumerate() {
            s.push_str(&format!(
                "{:.3},{:.3},{:.6},{:.3},{:.3},{:.3},{},{:.3},{}\n",
                p.cadence,
                p.expected_wall,
                p.expected_faults,
                p.expected_lost,
                p.checkpoint_overhead,
                p.walk_wall,
                p.walk_faults,
                p.walk_lost,
                if Some(i) == self.best { 1 } else { 0 },
            ));
        }
        s
    }

    /// Structured export (deterministic field order).
    pub fn to_json(&self) -> Json {
        let points = self
            .points
            .iter()
            .map(|p| {
                obj([
                    ("cadence_s", Json::from(p.cadence)),
                    ("expected_wall_s", Json::from(p.expected_wall)),
                    ("expected_faults", Json::from(p.expected_faults)),
                    ("expected_lost_s", Json::from(p.expected_lost)),
                    ("checkpoint_overhead_s", Json::from(p.checkpoint_overhead)),
                    ("walk_wall_s", Json::from(p.walk_wall)),
                    ("walk_faults", Json::from(p.walk_faults as f64)),
                    ("walk_lost_s", Json::from(p.walk_lost)),
                ])
            })
            .collect();
        obj([
            ("work_s", Json::from(self.work)),
            ("rate_per_s", Json::from(self.rate)),
            ("checkpoint_cost_s", Json::from(self.cost)),
            ("young_daly_s", Json::from(self.young_daly)),
            (
                "optimal_cadence_s",
                match self.best {
                    Some(b) => Json::from(self.points[b].cadence),
                    None => Json::Null,
                },
            ),
            ("points", Json::Arr(points)),
        ])
    }
}

/// Run a traffic scenario with periodic checkpointing: snapshot the
/// whole simulation at every multiple of `every` engine seconds,
/// round-trip each snapshot through its JSON wire format, and resume
/// it — until the stream drains. Returns the final report (bit-identical
/// to the uninterrupted run's) and the number of snapshot legs taken.
pub fn run_chained(
    spec: &TrafficSpec,
    catalog: &Catalog,
    cluster: &ClusterSpec,
    cfg: &EngineConfig,
    every: f64,
) -> Result<(TrafficReport, usize)> {
    run_chained_obs(spec, catalog, cluster, cfg, every, TrafficObs::default)
}

/// [`run_chained`] with observability attached to every leg.
///
/// `obs` is called once per leg (the initial run, then each resume) and
/// its attachments are installed on that leg's coordinator. Callers
/// that want one event stream spanning the whole chained run pass
/// shared handles — e.g. clone the same `Rc<RefCell<FileSink>>` and
/// `Rc<RefCell<EngineProfile>>` into each [`TrafficObs`] — so the
/// concatenated stream (modulo `checkpoint` seam markers) is
/// bit-identical to the uninterrupted run's, and lane counters
/// accumulate across legs.
pub fn run_chained_obs(
    spec: &TrafficSpec,
    catalog: &Catalog,
    cluster: &ClusterSpec,
    cfg: &EngineConfig,
    every: f64,
    mut obs: impl FnMut() -> TrafficObs,
) -> Result<(TrafficReport, usize)> {
    if !every.is_finite() || every <= 0.0 {
        return Err(Error::Config(format!(
            "checkpoint-every: cadence must be positive and finite, got {every}"
        )));
    }
    let mut spec = spec.clone();
    spec.checkpoint_at = Some(every);
    let mut outcome = run_traffic_resumable_obs(&spec, catalog, cluster, cfg, obs())?;
    let mut legs = 0usize;
    loop {
        match outcome {
            TrafficOutcome::Completed(rep) => return Ok((*rep, legs)),
            TrafficOutcome::Checkpointed(ck) => {
                legs += 1;
                // Every leg crosses the wire format: serialize, parse,
                // rebuild. A schema bug surfaces here, not in some
                // later real preemption.
                let wire = ck.to_json().to_string();
                let ck = TrafficCheckpoint::from_json(&Json::parse(&wire)?)?;
                // Next cadence multiple strictly past the snapshot
                // instant (the engine pauses within EPS of the target,
                // so a naive `every * (legs + 1)` could re-checkpoint
                // without progress).
                let mut k = (ck.sim.now / every).floor() + 1.0;
                while every * k <= ck.sim.now + EPS {
                    k += 1.0;
                }
                outcome = ck.resume_until_obs(None, Some(every * k), obs())?;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_walk_is_deterministic_and_increasing() {
        let mut a = FaultWalk::new(0.001, 42).unwrap();
        let mut b = FaultWalk::new(0.001, 42).unwrap();
        // Out-of-order access extends the same sequence.
        let t5 = a.time(5);
        assert_eq!(b.time(5), t5);
        assert_eq!(a.time(2), b.time(2));
        for i in 1..=5 {
            assert!(a.time(i) > a.time(i - 1));
        }
        let mut c = FaultWalk::new(0.001, 43).unwrap();
        assert_ne!(c.time(0), a.time(0));
        assert!(FaultWalk::new(0.0, 1).is_err());
    }

    #[test]
    fn sweep_is_bit_identical_per_seed() {
        let cadences = [100.0, 300.0, 1000.0, 3000.0];
        let a = sweep_cadence(20_000.0, 1e-3, 30.0, &cadences, 7).unwrap();
        let b = sweep_cadence(20_000.0, 1e-3, 30.0, &cadences, 7).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        // A different seed changes only the sampled-walk columns.
        let c = sweep_cadence(20_000.0, 1e-3, 30.0, &cadences, 8).unwrap();
        for (pa, pc) in a.points.iter().zip(&c.points) {
            assert_eq!(pa.expected_wall, pc.expected_wall);
            assert_eq!(pa.checkpoint_overhead, pc.checkpoint_overhead);
        }
        assert_eq!(a.best, c.best, "the optimum ranks on the expectation, not the draw");
    }

    #[test]
    fn expectation_model_matches_closed_form() {
        // One full segment + tail, hand-checked numbers: work 250,
        // cadence 100 -> segments of u = 100+C, 100+C, 50.
        let (rate, cost) = (1e-3, 20.0);
        let sw = sweep_cadence(250.0, rate, cost, &[100.0], 1).unwrap();
        let p = &sw.points[0];
        let e = |u: f64| ((rate * u).exp() - 1.0) / rate;
        let want_wall = e(120.0) + e(120.0) + e(50.0);
        assert!((p.expected_wall - want_wall).abs() < 1e-9, "{} vs {want_wall}", p.expected_wall);
        assert_eq!(p.checkpoint_overhead, 2.0 * cost);
        // Conservation: expected wall = work + checkpoint writes in
        // successful attempts + expected rework. The model folds the
        // successful writes into `u`, so wall - lost covers work plus
        // the two writes exactly.
        assert!((p.expected_wall - p.expected_lost - (250.0 + 2.0 * cost)).abs() < 1e-9);
        assert!((sw.young_daly - (2.0 * cost / rate).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn optimum_shifts_with_mtbf() {
        // Denser grid around the Young/Daly scale: with C = 30 s,
        // T*(MTBF 1e3) ~ 245 s and T*(MTBF 1e5) ~ 2449 s, so the
        // optimum must move right as the machine gets healthier.
        let cadences = [60.0, 250.0, 1000.0, 2500.0, 10_000.0];
        let fragile = sweep_cadence(50_000.0, 1e-3, 30.0, &cadences, 5).unwrap();
        let sturdy = sweep_cadence(50_000.0, 1e-5, 30.0, &cadences, 5).unwrap();
        let (bf, bs) = (fragile.best.unwrap(), sturdy.best.unwrap());
        assert!(
            cadences[bf] < cadences[bs],
            "fragile machine optimum {} should be shorter than sturdy {}",
            cadences[bf],
            cadences[bs]
        );
        assert!(fragile.young_daly < sturdy.young_daly);
        // The optimum is interior on this grid for the fragile case:
        // neither drowning in checkpoints nor in rework.
        assert!(bf != 0 && bf + 1 != cadences.len(), "optimum index {bf} is an extreme");
    }

    #[test]
    fn sampled_walk_conserves_time() {
        let sw = sweep_cadence(30_000.0, 2e-4, 25.0, &[500.0, 2000.0], 11).unwrap();
        for p in &sw.points {
            assert!(p.walk_wall.is_finite());
            // Sampled path: wall = work + checkpoint writes + rework.
            let writes = p.checkpoint_overhead;
            let got = p.walk_wall - p.walk_lost - writes;
            assert!(
                (got - 30_000.0).abs() < 1e-6,
                "cadence {}: wall {} lost {} writes {}",
                p.cadence,
                p.walk_wall,
                p.walk_lost,
                writes
            );
        }
    }

    #[test]
    fn sweep_rejects_garbage() {
        assert!(sweep_cadence(0.0, 1e-3, 1.0, &[10.0], 1).is_err());
        assert!(sweep_cadence(100.0, 0.0, 1.0, &[10.0], 1).is_err());
        assert!(sweep_cadence(100.0, 1e-3, -1.0, &[10.0], 1).is_err());
        assert!(sweep_cadence(100.0, 1e-3, 1.0, &[], 1).is_err());
        assert!(sweep_cadence(100.0, 1e-3, 1.0, &[0.0], 1).is_err());
    }
}
