//! Failure injection & resilience: node faults, retry/backoff, and
//! the bookkeeping behind the resilience report.
//!
//! HPC allocations fail: Summit-class machines lose nodes to hardware
//! faults mid-job, and preemptible/backfill allocations are revoked
//! with no drain window. The RADICAL-Pilot leadership-platform
//! characterization treats partial resource failure as a first-class
//! pilot concern; this module gives the simulator that failure model,
//! deterministically.
//!
//! Two fault sources compose in a [`FailureSpec`]:
//!
//! - **MTBF process** — each schedulable node fails with rate
//!   `1/mtbf`, GPU nodes scaled by
//!   [`gpu_factor`](FailureSpec::gpu_factor) (accelerator boards
//!   dominate leadership-class fault logs). The superposed process is
//!   sampled with the crate [`Rng`]'s exponential draws from a
//!   dedicated forked stream, so the fault schedule is a pure function
//!   of the engine seed.
//! - **Trace replay** — explicit `t:node` preemption events (CLI
//!   `--trace 3600:0,7200:5`), replayed verbatim. The deterministic
//!   backbone of the kill-path tests.
//!
//! A node failure is a **hard kill**, distinct from the graceful drain
//! of [`Allocator::drain_node`](crate::resources::Allocator::drain_node):
//! in-flight tasks on the node are lost, their partial work is
//! discounted as `lost_*` in [`ResilienceStats`], and the node returns
//! to service immediately (fail-stop-restart). Killed tasks flow into
//! the per-workflow [`RetryPolicy`]: bounded attempts, exponential
//! backoff with jitter drawn from the task's own stateless RNG stream,
//! and requeue *through the scheduler* — fair-share and backfill
//! policies see a retry as an ordinary submission.
//!
//! Everything here is plain data (`Clone + PartialEq`) with paired
//! [`ToJson`]/[`FromJson`] impls: the live process state
//! ([`FailureState`]) rides inside the simulation snapshot, so
//! checkpoint/resume reproduces the fault schedule bit-identically.

pub mod cadence;

use crate::error::{Error, Result};
use crate::util::json::{arr_of, f64_or_nan, from_f64_nan, obj, parse_arr, FromJson, Json, ToJson};
use crate::util::rng::{Rng, RngState};

/// Stream tag for the fault-process RNG fork (`"FAIL"`).
const FAULT_TAG: u64 = 0x4641_494c;
/// Seed salt for the per-(task, attempt) backoff-jitter streams
/// (`"JITT"`).
const JITTER_TAG: u64 = 0x4a49_5454;

/// One trace-driven preemption: node `node` fails at engine time `at`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureEvent {
    /// Engine time (seconds, >= 0) of the failure.
    pub at: f64,
    /// Cluster node index to kill.
    pub node: usize,
}

impl ToJson for FailureEvent {
    fn to_json(&self) -> Json {
        obj([("at", Json::from(self.at)), ("node", Json::from(self.node))])
    }
}

impl FromJson for FailureEvent {
    fn from_json(v: &Json) -> Result<FailureEvent> {
        Ok(FailureEvent { at: v.req_f64("at")?, node: v.req_u64("node")? as usize })
    }
}

/// Retry discipline for tasks killed by a node failure.
///
/// Attempt `k` (1-based) of a killed task is requeued after
/// `base * factor^(k-1) * (1 + jitter * u)` seconds, where `u` is a
/// uniform draw from a stateless stream keyed by `(seed, uid, k)` —
/// nothing to snapshot, and two tasks killed by the same fault do not
/// thunder back in lock-step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Maximum retry attempts per task; `0` means unlimited.
    pub max_attempts: u32,
    /// First-retry backoff in engine seconds (>= 0).
    pub base: f64,
    /// Multiplicative backoff growth per attempt (>= 1).
    pub factor: f64,
    /// Jitter fraction in `[0, 1]`: the delay is stretched by up to
    /// this fraction, never shrunk below the deterministic backoff.
    pub jitter: f64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_attempts: 3, base: 30.0, factor: 2.0, jitter: 0.1 }
    }
}

impl RetryPolicy {
    /// Parse the CLI retry spec `"max:4,base:30,factor:2,jitter:0.25"`.
    /// Unlisted keys keep their [`Default`] values.
    pub fn parse(spec: &str) -> Result<RetryPolicy> {
        let mut p = RetryPolicy::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part.split_once(':').ok_or_else(|| {
                Error::Config(format!("--retry: expected key:value, got '{part}'"))
            })?;
            let v = v.trim();
            match k.trim() {
                "max" => {
                    p.max_attempts = v.parse().map_err(|_| {
                        Error::Config(format!("--retry: bad max attempts in '{part}'"))
                    })?;
                }
                "base" => {
                    p.base = v.parse().map_err(|_| {
                        Error::Config(format!("--retry: bad base delay in '{part}'"))
                    })?;
                }
                "factor" => {
                    p.factor = v.parse().map_err(|_| {
                        Error::Config(format!("--retry: bad factor in '{part}'"))
                    })?;
                }
                "jitter" => {
                    p.jitter = v.parse().map_err(|_| {
                        Error::Config(format!("--retry: bad jitter in '{part}'"))
                    })?;
                }
                other => {
                    return Err(Error::Config(format!("--retry: unknown key '{other}'")));
                }
            }
        }
        p.validate()?;
        Ok(p)
    }

    fn validate(&self) -> Result<()> {
        if !self.base.is_finite() || self.base < 0.0 {
            return Err(Error::Config(format!(
                "retry policy: base delay must be finite and >= 0, got {}",
                self.base
            )));
        }
        if !self.factor.is_finite() || self.factor < 1.0 {
            return Err(Error::Config(format!(
                "retry policy: factor must be >= 1, got {}",
                self.factor
            )));
        }
        if !self.jitter.is_finite() || !(0.0..=1.0).contains(&self.jitter) {
            return Err(Error::Config(format!(
                "retry policy: jitter must be in [0, 1], got {}",
                self.jitter
            )));
        }
        Ok(())
    }

    /// Whether retry attempt `attempt` (1-based) is still allowed.
    pub fn allows(&self, attempt: u32) -> bool {
        self.max_attempts == 0 || attempt <= self.max_attempts
    }

    /// Backoff delay for retry `attempt` (1-based) of task `uid`.
    ///
    /// The jitter draw comes from a stream keyed by
    /// `(engine seed, uid, attempt)` — a pure function, so a snapshot
    /// taken mid-backoff needs only the already-computed due time.
    pub fn delay(&self, seed: u64, uid: usize, attempt: u32) -> f64 {
        let mut rng = Rng::new(seed ^ JITTER_TAG).fork(uid as u64).fork(attempt as u64);
        let exp = attempt.saturating_sub(1).min(i32::MAX as u32) as i32;
        let scale = self.base * self.factor.powi(exp);
        scale * (1.0 + self.jitter * rng.f64())
    }
}

impl ToJson for RetryPolicy {
    fn to_json(&self) -> Json {
        obj([
            ("max_attempts", Json::from(self.max_attempts as u64)),
            ("base", Json::from(self.base)),
            ("factor", Json::from(self.factor)),
            ("jitter", Json::from(self.jitter)),
        ])
    }
}

impl FromJson for RetryPolicy {
    fn from_json(v: &Json) -> Result<RetryPolicy> {
        let p = RetryPolicy {
            max_attempts: v.req_u64("max_attempts")? as u32,
            base: v.req_f64("base")?,
            factor: v.req_f64("factor")?,
            jitter: v.req_f64("jitter")?,
        };
        p.validate()?;
        Ok(p)
    }
}

/// Failure-injection scenario: fault sources plus the retry discipline
/// applied to their victims. Part of a traffic scenario's identity —
/// the same seed and spec reproduce a bit-identical run.
#[derive(Debug, Clone, PartialEq)]
pub struct FailureSpec {
    /// Per-node mean time between failures in engine seconds; `None`
    /// disables the stochastic process (trace replay still applies).
    pub mtbf: Option<f64>,
    /// Fault-rate multiplier for nodes with GPUs (>= 0; 1 = no bias).
    pub gpu_factor: f64,
    /// Trace-driven preemptions, replayed in time order.
    pub trace: Vec<FailureEvent>,
    /// Retry discipline for killed tasks.
    pub retry: RetryPolicy,
}

impl Default for FailureSpec {
    fn default() -> FailureSpec {
        FailureSpec {
            mtbf: None,
            gpu_factor: 1.0,
            trace: Vec::new(),
            retry: RetryPolicy::default(),
        }
    }
}

impl FailureSpec {
    /// Spec with only the stochastic MTBF process enabled.
    pub fn mtbf(mtbf: f64) -> FailureSpec {
        FailureSpec { mtbf: Some(mtbf), ..FailureSpec::default() }
    }

    /// Parse the CLI trace spec `"t:node,t:node,..."` into a spec with
    /// only trace replay enabled.
    pub fn parse_trace(spec: &str) -> Result<FailureSpec> {
        let mut trace = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (t, n) = part.split_once(':').ok_or_else(|| {
                Error::Config(format!("--trace: expected t:node, got '{part}'"))
            })?;
            let at: f64 = t
                .trim()
                .parse()
                .map_err(|_| Error::Config(format!("--trace: bad time in '{part}'")))?;
            let node: usize = n
                .trim()
                .parse()
                .map_err(|_| Error::Config(format!("--trace: bad node index in '{part}'")))?;
            trace.push(FailureEvent { at, node });
        }
        if trace.is_empty() {
            return Err(Error::Config(format!("--trace: no events in '{spec}'")));
        }
        let spec = FailureSpec { trace, ..FailureSpec::default() };
        spec.validate()?;
        Ok(spec)
    }

    /// Whether any fault source is configured.
    pub fn is_active(&self) -> bool {
        self.mtbf.is_some() || !self.trace.is_empty()
    }

    /// Check the spec is well-formed (positive finite MTBF, finite
    /// non-negative trace times, sane retry policy).
    pub fn validate(&self) -> Result<()> {
        if let Some(m) = self.mtbf {
            if !m.is_finite() || m <= 0.0 {
                return Err(Error::Config(format!(
                    "failure spec: MTBF must be positive and finite, got {m}"
                )));
            }
        }
        if !self.gpu_factor.is_finite() || self.gpu_factor < 0.0 {
            return Err(Error::Config(format!(
                "failure spec: gpu_factor must be finite and >= 0, got {}",
                self.gpu_factor
            )));
        }
        for e in &self.trace {
            if !e.at.is_finite() || e.at < 0.0 {
                return Err(Error::Config(format!(
                    "failure spec: invalid trace event time {}",
                    e.at
                )));
            }
        }
        self.retry.validate()
    }
}

impl ToJson for FailureSpec {
    fn to_json(&self) -> Json {
        obj([
            (
                "mtbf",
                match self.mtbf {
                    Some(m) => Json::from(m),
                    None => Json::Null,
                },
            ),
            ("gpu_factor", Json::from(self.gpu_factor)),
            ("trace", arr_of(&self.trace)),
            ("retry", self.retry.to_json()),
        ])
    }
}

impl FromJson for FailureSpec {
    fn from_json(v: &Json) -> Result<FailureSpec> {
        let mtbf = match v.get("mtbf") {
            Json::Null => None,
            m => Some(m.as_f64().ok_or_else(|| Error::Config("failure spec: bad mtbf".into()))?),
        };
        let spec = FailureSpec {
            mtbf,
            gpu_factor: v.req_f64("gpu_factor")?,
            trace: parse_arr(v, "trace")?,
            retry: RetryPolicy::from_json(v.get("retry"))?,
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// One killed task waiting out its retry backoff: resubmitted through
/// the scheduler at `due`. Snapshot-visible — a checkpoint taken
/// mid-backoff carries these verbatim.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryEntry {
    /// Coordinator-global task uid (stays live while waiting).
    pub uid: usize,
    /// Engine time at which the task is resubmitted.
    pub due: f64,
    /// Which retry attempt this is (1-based).
    pub attempt: u32,
}

impl ToJson for RetryEntry {
    fn to_json(&self) -> Json {
        obj([
            ("uid", Json::from(self.uid)),
            ("due", Json::from(self.due)),
            ("attempt", Json::from(self.attempt as u64)),
        ])
    }
}

impl FromJson for RetryEntry {
    fn from_json(v: &Json) -> Result<RetryEntry> {
        Ok(RetryEntry {
            uid: v.req_u64("uid")? as usize,
            due: v.req_f64("due")?,
            attempt: v.req_u64("attempt")? as u32,
        })
    }
}

/// Resilience accounting for one run: what the failures cost and what
/// survived them. `goodput + lost` equals the busy resource-time the
/// cluster actually delivered (the conservation invariant enforced by
/// `tests/resilience.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ResilienceStats {
    /// Node-failure events injected (MTBF fires + trace replays).
    pub failures_injected: u64,
    /// Running tasks hard-killed by those failures.
    pub tasks_killed: u64,
    /// Retries scheduled (killed tasks granted another attempt).
    pub retries_scheduled: u64,
    /// Tasks whose retry budget ran out.
    pub retries_exhausted: u64,
    /// Core-seconds of partial work destroyed by kills.
    pub lost_core_s: f64,
    /// GPU-seconds of partial work destroyed by kills.
    pub lost_gpu_s: f64,
    /// Core-seconds of work that completed (survived to a finish).
    pub goodput_core_s: f64,
    /// GPU-seconds of work that completed.
    pub goodput_gpu_s: f64,
}

impl ToJson for ResilienceStats {
    fn to_json(&self) -> Json {
        obj([
            ("failures_injected", Json::from(self.failures_injected as f64)),
            ("tasks_killed", Json::from(self.tasks_killed as f64)),
            ("retries_scheduled", Json::from(self.retries_scheduled as f64)),
            ("retries_exhausted", Json::from(self.retries_exhausted as f64)),
            ("lost_core_s", Json::from(self.lost_core_s)),
            ("lost_gpu_s", Json::from(self.lost_gpu_s)),
            ("goodput_core_s", Json::from(self.goodput_core_s)),
            ("goodput_gpu_s", Json::from(self.goodput_gpu_s)),
        ])
    }
}

impl FromJson for ResilienceStats {
    fn from_json(v: &Json) -> Result<ResilienceStats> {
        Ok(ResilienceStats {
            failures_injected: v.req_u64("failures_injected")?,
            tasks_killed: v.req_u64("tasks_killed")?,
            retries_scheduled: v.req_u64("retries_scheduled")?,
            retries_exhausted: v.req_u64("retries_exhausted")?,
            lost_core_s: v.req_f64("lost_core_s")?,
            lost_gpu_s: v.req_f64("lost_gpu_s")?,
            goodput_core_s: v.req_f64("goodput_core_s")?,
            goodput_gpu_s: v.req_f64("goodput_gpu_s")?,
        })
    }
}

/// Live fault-injection state: the spec, the forked RNG stream, the
/// pre-drawn next stochastic fault, the trace replay cursor, and the
/// running [`ResilienceStats`]. Owned by the engine loop; serialized
/// as [`FailureState`] inside the simulation snapshot.
#[derive(Debug, Clone)]
pub struct FailureProcess {
    /// The scenario being injected.
    pub spec: FailureSpec,
    rng: Rng,
    /// Engine time of the next stochastic fault (`NaN` = none armed).
    pub next_fault: f64,
    trace_cursor: usize,
    /// Running resilience accounting for this run.
    pub stats: ResilienceStats,
}

impl FailureProcess {
    /// Build the process for one run. The RNG is forked from the
    /// engine seed with a dedicated tag, so the fault schedule is
    /// independent of every other stream; the trace is sorted by time
    /// (ties by node index) for replay.
    pub fn new(mut spec: FailureSpec, seed: u64) -> FailureProcess {
        spec.trace
            .sort_by(|a, b| a.at.total_cmp(&b.at).then(a.node.cmp(&b.node)));
        FailureProcess {
            spec,
            rng: Rng::new(seed).fork(FAULT_TAG),
            next_fault: f64::NAN,
            trace_cursor: 0,
            stats: ResilienceStats::default(),
        }
    }

    /// Draw the next stochastic fault time from `now` given the
    /// current superposed fault rate (sum of per-node rates). A zero
    /// rate (or no MTBF configured) disarms the process.
    ///
    /// The rate is sampled at draw time; capacity changes between
    /// draws do not reshuffle an already-drawn fault (the exponential
    /// is memoryless, and redrawing on every resize would make the
    /// schedule depend on loop internals instead of the seed).
    pub fn draw_next(&mut self, now: f64, total_rate: f64) {
        self.next_fault = if self.spec.mtbf.is_some() && total_rate > 0.0 {
            now + self.rng.exp(total_rate)
        } else {
            f64::NAN
        };
    }

    /// Pick the node the due fault lands on: a weighted draw over
    /// `(node, rate)` pairs. Exactly one uniform variate is consumed
    /// per call, victims or not, so RNG consumption is a pure function
    /// of the fault count.
    pub fn pick_victim(&mut self, weights: &[(usize, f64)]) -> Option<usize> {
        let total: f64 = weights.iter().map(|w| w.1).sum();
        let u = self.rng.f64() * total;
        if !(total > 0.0) {
            return None;
        }
        let mut acc = 0.0;
        for &(node, w) in weights {
            acc += w;
            if u < acc {
                return Some(node);
            }
        }
        weights.last().map(|w| w.0)
    }

    /// Pop the next trace preemption due at or before `now + eps`, if
    /// any, advancing the replay cursor.
    pub fn trace_due(&mut self, now: f64, eps: f64) -> Option<FailureEvent> {
        let ev = *self.spec.trace.get(self.trace_cursor)?;
        if ev.at <= now + eps {
            self.trace_cursor += 1;
            Some(ev)
        } else {
            None
        }
    }

    /// Engine time of the next failure from either source (`NaN` if
    /// neither is pending) — the value the engine loop folds into its
    /// horizon / `Failure` calendar lane.
    pub fn next_event(&self) -> f64 {
        let trace_next = self.spec.trace.get(self.trace_cursor).map_or(f64::NAN, |e| e.at);
        match (self.next_fault.is_nan(), trace_next.is_nan()) {
            (true, true) => f64::NAN,
            (true, false) => trace_next,
            (false, true) => self.next_fault,
            (false, false) => self.next_fault.min(trace_next),
        }
    }

    /// Snapshot the live state (RNG position included).
    pub fn state(&self) -> FailureState {
        FailureState {
            spec: self.spec.clone(),
            rng: self.rng.state(),
            next_fault: self.next_fault,
            trace_cursor: self.trace_cursor,
            stats: self.stats,
        }
    }

    /// Rebuild the process from a snapshot, mid-stream.
    pub fn from_state(s: &FailureState) -> FailureProcess {
        FailureProcess {
            spec: s.spec.clone(),
            rng: Rng::from_state(&s.rng),
            next_fault: s.next_fault,
            trace_cursor: s.trace_cursor,
            stats: s.stats,
        }
    }
}

/// Serialized [`FailureProcess`]: everything needed to resume the
/// fault schedule bit-identically — the spec, the RNG stream position,
/// the pre-drawn next fault, the trace cursor, and the cumulative
/// stats. Carried by the simulation snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct FailureState {
    /// The injected scenario.
    pub spec: FailureSpec,
    /// Fault-stream RNG position.
    pub rng: RngState,
    /// Pre-drawn next stochastic fault time (`NaN` = disarmed).
    pub next_fault: f64,
    /// Trace replay position.
    pub trace_cursor: usize,
    /// Cumulative resilience accounting up to the snapshot instant.
    pub stats: ResilienceStats,
}

impl ToJson for FailureState {
    fn to_json(&self) -> Json {
        obj([
            ("spec", self.spec.to_json()),
            ("rng", self.rng.to_json()),
            ("next_fault", from_f64_nan(self.next_fault)),
            ("trace_cursor", Json::from(self.trace_cursor)),
            ("stats", self.stats.to_json()),
        ])
    }
}

impl FromJson for FailureState {
    fn from_json(v: &Json) -> Result<FailureState> {
        Ok(FailureState {
            spec: FailureSpec::from_json(v.get("spec"))?,
            rng: RngState::from_json(v.get("rng"))?,
            next_fault: f64_or_nan(v.get("next_fault"))?,
            trace_cursor: v.req_u64("trace_cursor")? as usize,
            stats: ResilienceStats::from_json(v.get("stats"))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_parse_accepts_partial_specs() {
        let p = RetryPolicy::parse("max:4,base:30,factor:2,jitter:0.25").unwrap();
        assert_eq!(p.max_attempts, 4);
        assert_eq!(p.base, 30.0);
        assert_eq!(p.factor, 2.0);
        assert_eq!(p.jitter, 0.25);
        // Unlisted keys keep their defaults.
        let p = RetryPolicy::parse("max:0").unwrap();
        assert_eq!(p.max_attempts, 0);
        assert_eq!(p.base, RetryPolicy::default().base);
    }

    #[test]
    fn retry_parse_rejects_garbage() {
        assert!(RetryPolicy::parse("max").is_err());
        assert!(RetryPolicy::parse("max:x").is_err());
        assert!(RetryPolicy::parse("nope:1").is_err());
        assert!(RetryPolicy::parse("factor:0.5").is_err());
        assert!(RetryPolicy::parse("jitter:2").is_err());
        assert!(RetryPolicy::parse("base:-1").is_err());
    }

    #[test]
    fn retry_delay_is_deterministic_and_grows() {
        let p = RetryPolicy { max_attempts: 0, base: 10.0, factor: 2.0, jitter: 0.5 };
        let d1 = p.delay(42, 7, 1);
        assert_eq!(d1, p.delay(42, 7, 1), "same key, same delay");
        // Jitter only stretches: delay stays within [scale, scale*(1+j)].
        assert!((10.0..=15.0).contains(&d1), "got {d1}");
        let d2 = p.delay(42, 7, 2);
        assert!((20.0..=30.0).contains(&d2), "got {d2}");
        // Different uid / attempt / seed give different jitter.
        assert_ne!(p.delay(42, 8, 1), d1);
        assert_ne!(p.delay(43, 7, 1), d1);
    }

    #[test]
    fn retry_allows_caps_and_unlimited() {
        let capped = RetryPolicy { max_attempts: 2, ..RetryPolicy::default() };
        assert!(capped.allows(1) && capped.allows(2) && !capped.allows(3));
        let unlimited = RetryPolicy { max_attempts: 0, ..RetryPolicy::default() };
        assert!(unlimited.allows(1_000_000));
    }

    #[test]
    fn trace_parse_and_replay_order() {
        let spec = FailureSpec::parse_trace("7200:5, 3600:0").unwrap();
        assert_eq!(spec.trace.len(), 2);
        assert!(spec.is_active());
        // The process replays in time order regardless of spec order.
        let mut fp = FailureProcess::new(spec, 1);
        assert_eq!(fp.next_event(), 3600.0);
        let e = fp.trace_due(3600.0, 1e-9).unwrap();
        assert_eq!((e.at, e.node), (3600.0, 0));
        assert!(fp.trace_due(3600.0, 1e-9).is_none(), "next event not due yet");
        assert_eq!(fp.next_event(), 7200.0);
    }

    #[test]
    fn trace_parse_rejects_garbage() {
        assert!(FailureSpec::parse_trace("").is_err());
        assert!(FailureSpec::parse_trace("3600").is_err());
        assert!(FailureSpec::parse_trace("x:0").is_err());
        assert!(FailureSpec::parse_trace("3600:gpu").is_err());
        assert!(FailureSpec::parse_trace("-5:0").is_err());
    }

    #[test]
    fn mtbf_process_draws_deterministically() {
        let mut a = FailureProcess::new(FailureSpec::mtbf(1000.0), 42);
        let mut b = FailureProcess::new(FailureSpec::mtbf(1000.0), 42);
        a.draw_next(0.0, 0.01);
        b.draw_next(0.0, 0.01);
        assert_eq!(a.next_fault, b.next_fault);
        assert!(a.next_fault > 0.0 && a.next_fault.is_finite());
        // A different seed gives a different schedule.
        let mut c = FailureProcess::new(FailureSpec::mtbf(1000.0), 43);
        c.draw_next(0.0, 0.01);
        assert_ne!(c.next_fault, a.next_fault);
        // Zero rate disarms.
        a.draw_next(0.0, 0.0);
        assert!(a.next_fault.is_nan());
        assert!(a.next_event().is_nan());
    }

    #[test]
    fn pick_victim_is_weighted_and_consumes_one_draw() {
        let mut fp = FailureProcess::new(FailureSpec::mtbf(100.0), 7);
        // All the weight on node 3: it is always picked.
        for _ in 0..16 {
            assert_eq!(fp.pick_victim(&[(1, 0.0), (3, 5.0)]), Some(3));
        }
        // Empty / zero-weight sets pick nothing but still consume a
        // draw — RNG use is a pure function of the fault count.
        let s0 = fp.state();
        assert_eq!(fp.pick_victim(&[]), None);
        assert_ne!(fp.state().rng, s0.rng);
        assert_eq!(fp.pick_victim(&[(0, 0.0)]), None);
    }

    #[test]
    fn state_round_trips_through_json() {
        let mut spec = FailureSpec::mtbf(500.0);
        spec.gpu_factor = 2.5;
        spec.trace.push(FailureEvent { at: 100.0, node: 1 });
        spec.retry = RetryPolicy { max_attempts: 5, base: 12.0, factor: 1.5, jitter: 0.3 };
        let mut fp = FailureProcess::new(spec, 99);
        fp.draw_next(0.0, 0.02);
        let _ = fp.trace_due(100.0, 1e-9);
        fp.stats.failures_injected = 3;
        fp.stats.lost_core_s = 1234.5;
        let state = fp.state();
        let wire = state.to_json().to_string();
        let back = FailureState::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, state);
        // The rebuilt process continues the same RNG stream.
        let mut resumed = FailureProcess::from_state(&back);
        let mut straight = fp.clone();
        straight.draw_next(50.0, 0.02);
        resumed.draw_next(50.0, 0.02);
        assert_eq!(straight.next_fault, resumed.next_fault);
        // NaN next_fault survives the wire format too.
        let mut disarmed = FailureProcess::new(FailureSpec::default(), 1);
        disarmed.draw_next(0.0, 0.0);
        let s = disarmed.state();
        let back =
            FailureState::from_json(&Json::parse(&s.to_json().to_string()).unwrap()).unwrap();
        assert!(back.next_fault.is_nan());
    }

    #[test]
    fn spec_validation_bites() {
        assert!(FailureSpec { mtbf: Some(0.0), ..FailureSpec::default() }.validate().is_err());
        assert!(FailureSpec { mtbf: Some(f64::NAN), ..FailureSpec::default() }
            .validate()
            .is_err());
        assert!(FailureSpec { gpu_factor: -1.0, ..FailureSpec::default() }.validate().is_err());
        assert!(FailureSpec::default().validate().is_ok());
        assert!(!FailureSpec::default().is_active());
    }

    #[test]
    fn retry_entry_round_trips() {
        let e = RetryEntry { uid: 17, due: 345.25, attempt: 2 };
        let back =
            RetryEntry::from_json(&Json::parse(&e.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, e);
    }
}
