//! Quickstart: the paper's §5.3 worked example, end to end.
//!
//! Builds the Fig. 2b workflow (one fork, two chains), predicts the
//! benefit of asynchronous execution with the analytical model
//! (Eqns 1–5), then *measures* it with the discrete-event engine — the
//! same pattern you would use to decide whether your own workflow is
//! worth restructuring.
//!
//! Run: `cargo run --release --example quickstart`

use asyncflow::dag::figures;
use asyncflow::engine::{simulate_cfg, EngineConfig, ExecutionMode};
use asyncflow::entk::{Pipeline, Workflow};
use asyncflow::model;
use asyncflow::resources::{ClusterSpec, ResourceRequest};
use asyncflow::task::TaskSetSpec;

fn main() {
    // --- 1. Describe the workflow (Fig. 2b + §5.3 TX assignments) ----
    let dag = figures::fig2b();
    let tx = [500.0, 1000.0, 1000.0, 2000.0, 4000.0, 2000.0];
    let sets: Vec<TaskSetSpec> = (0..6)
        .map(|i| {
            TaskSetSpec::new(format!("T{i}"), 1, ResourceRequest::new(4, 0), tx[i])
                .with_sigma(0.0)
        })
        .collect();
    let wf = Workflow {
        name: "fig2b-worked-example".into(),
        sets,
        dag,
        // Sequential: stage per rank.
        sequential: vec![Pipeline::new("seq")
            .stage(&[0])
            .stage(&[1, 2])
            .stage(&[3, 4])
            .stage(&[5])],
        // Asynchronous: chains H1 = {T1,T3,T5} and H2 = {T2,T4}.
        asynchronous: vec![
            Pipeline::new("p0").stage(&[0]),
            Pipeline::new("H1").stage(&[1]).stage(&[3]).stage(&[5]),
            Pipeline::new("H2").stage(&[2]).stage(&[4]),
        ],
    };
    wf.validate().expect("valid workflow");

    let cluster = ClusterSpec::uniform("lab", 2, 16, 0);

    // --- 2. Predict (the paper's model, before running anything) -----
    let pred = model::predict(&wf, &cluster);
    println!("== prediction (Eqns 1-5)");
    println!("  DOA_dep = {}  DOA_res = {}  WLA = {}", pred.doa_dep, pred.doa_res, pred.wla);
    println!("  tSeq    = {:.0} s   (paper: 7500 s + overheads)", pred.t_seq);
    println!("  tAsync  = {:.0} s   (paper: 5500 s + overheads)", pred.t_async);
    println!("  I       = {:+.3}    (paper: ~0.26)", pred.improvement);

    // --- 3. Measure (discrete-event simulation of the pilot) ---------
    let cfg = EngineConfig::ideal();
    let seq = simulate_cfg(&wf, &cluster, ExecutionMode::Sequential, &cfg);
    let asy = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
    println!("\n== measured (virtual pilot, zero overheads)");
    println!(
        "  sequential   TTX = {:.0} s, cpu util {:.1}%",
        seq.makespan,
        seq.cpu_utilization * 100.0
    );
    println!(
        "  asynchronous TTX = {:.0} s, cpu util {:.1}%",
        asy.makespan,
        asy.cpu_utilization * 100.0
    );
    println!("  I = {:+.3}", asy.improvement_over(&seq));

    // --- 4. Where did the time go? TX masking (§5.3) ----------------
    let mask = model::masking_report(&wf, &cluster);
    println!("\n== masking report (critical path {:.0} s)", mask.critical_path);
    for s in &mask.sets {
        println!(
            "  {:<4} dur {:>6.0}s  slack {:>6.0}s  {}",
            s.set_name,
            s.duration,
            s.slack,
            if s.masked { "masked" } else { "on critical path" }
        );
    }

    assert!((seq.makespan - 7500.0).abs() < 1.0);
    assert!((asy.makespan - 5500.0).abs() < 1.0);
    println!("\nquickstart OK — simulator matches the paper's closed-form example");
}
