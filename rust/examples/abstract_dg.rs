//! Abstract-DG study (§6.2/§7.2/§7.3): c-DG1 vs c-DG2 — when does
//! asynchronicity pay?
//!
//! Both workflows share Fig. 3b's dependency graph; only the task
//! parameters (Table 2) differ. c-DG1's asynchronous sets are too short
//! for masking to beat the extra overheads (I < 0); c-DG2's long
//! {T3,T6} sets mask the whole {T4,T5} -> T7 chain (I ~ 0.26).
//!
//! Run: `cargo run --release --example abstract_dg`

use asyncflow::engine::{simulate_cfg, ExecutionMode};
use asyncflow::experiments::paper_engine_config;
use asyncflow::metrics::ascii_timeline;
use asyncflow::model;
use asyncflow::resources::ClusterSpec;
use asyncflow::workflows::{cdg1, cdg2};

fn main() {
    let cluster = ClusterSpec::summit_8gpu();
    for wf in [cdg1(), cdg2()] {
        println!("====================================================");
        println!("workflow {} on {}", wf.name, cluster.name);
        let pred = model::predict(&wf, &cluster);
        println!(
            "  model:    DOA_dep={} DOA_res={} WLA={}  tSeq={:.0}  tAsync={:.0}  I={:+.3}",
            pred.doa_dep, pred.doa_res, pred.wla, pred.t_seq, pred.t_async, pred.improvement
        );

        let cfg = paper_engine_config(42);
        let seq = simulate_cfg(&wf, &cluster, ExecutionMode::Sequential, &cfg);
        let asy = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
        println!(
            "  measured: tSeq={:.0}  tAsync={:.0}  I={:+.3}",
            seq.makespan,
            asy.makespan,
            asy.improvement_over(&seq)
        );
        println!(
            "  verdict:  {}",
            if asy.improvement_over(&seq) > 0.02 {
                "asynchronous execution pays off (c-DG2-like)"
            } else {
                "stay sequential (c-DG1-like: masking gains < async overheads)"
            }
        );

        // The paper's Figs. 5/6, as ASCII:
        println!("\n  -- asynchronous utilization timeline --");
        println!("{}", indent(&ascii_timeline(&asy.trace, 64, 5), 2));

        // Resource sensitivity: the same workloads on the strict 96-GPU
        // profile (Table 2's c-DG2 rank-2 demand exceeds it; masking is
        // clipped and the advantage shrinks).
        let strict = ClusterSpec::summit_paper();
        let seq96 = simulate_cfg(&wf, &strict, ExecutionMode::Sequential, &cfg);
        let asy96 = simulate_cfg(&wf, &strict, ExecutionMode::Asynchronous, &cfg);
        println!(
            "  on {}: tSeq={:.0} tAsync={:.0} I={:+.3} (resource-clipped)",
            strict.name,
            seq96.makespan,
            asy96.makespan,
            asy96.improvement_over(&seq96)
        );
    }
}

fn indent(s: &str, n: usize) -> String {
    let pad = " ".repeat(n);
    s.lines().map(|l| format!("{pad}{l}\n")).collect()
}
