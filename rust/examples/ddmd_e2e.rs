//! End-to-end DeepDriveMD with REAL ML compute — the full three-layer
//! stack on a real (small) workload.
//!
//! This is the system's proof of composition:
//!
//!   Rust engine (L3) -> pilot scheduler -> MlExecutor task bodies
//!     -> PJRT runtime -> AOT HLO artifacts (L2 JAX autoencoder + MD)
//!     -> Pallas kernels (L1 blocked matmul / distances / LJ forces)
//!
//! The workflow runs Lennard-Jones MD simulations, featurizes frames
//! into contact maps, aggregates them into batches, trains the
//! autoencoder with SGD (logging the loss curve), and scores
//! conformations by reconstruction error — DeepDriveMD's outlier-driven
//! loop — in both sequential and asynchronous modes, reporting the
//! measured relative improvement I.
//!
//! Requires `make artifacts` first.
//!
//! Run: `cargo run --release --example ddmd_e2e [-- --iterations 2]`

use asyncflow::ddmd::mlexec::MlExecutor;
use asyncflow::ddmd::{ddmd_workflow, DdmdConfig};
use asyncflow::engine::{run, EngineConfig, ExecutionMode};
use asyncflow::resources::ClusterSpec;
use asyncflow::runtime::RuntimeService;
use asyncflow::util::cli::Args;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
}

fn main() -> asyncflow::Result<()> {
    let args = Args::from_env(&[])?;
    let mut ddmd_cfg = DdmdConfig::small();
    ddmd_cfg.iterations = args.get_usize("iterations", ddmd_cfg.iterations)?;
    ddmd_cfg.train_steps = args.get_usize("train-steps", ddmd_cfg.train_steps)?;

    let wf = ddmd_workflow(&ddmd_cfg);
    let cluster = ClusterSpec::local_small();
    let engine_cfg = EngineConfig { task_overhead: 0.0, stage_overhead: 0.0, ..Default::default() };

    let svc = RuntimeService::start(artifacts_dir())?;
    println!(
        "runtime up: artifacts = {:?}",
        artifacts_dir().canonicalize().unwrap_or_default()
    );

    let mut results = Vec::new();
    for mode in [ExecutionMode::Sequential, ExecutionMode::Asynchronous] {
        // Fresh executor (and model parameters) per mode for a fair race.
        let mut ml = MlExecutor::new(svc.handle(), 7);
        let store = ml.store();
        let t0 = std::time::Instant::now();
        let rep = run(&wf, &cluster, mode, &engine_cfg, &mut ml)?;
        let wall = t0.elapsed().as_secs_f64();

        let st = store.lock().unwrap();
        println!("\n== {} mode: wall {:.1}s, engine TTX {:.1}s", mode.label(), wall, rep.makespan);
        println!(
            "   tasks {} | frames {} | batches {} | train steps {} | inferences {}",
            rep.records.len(),
            st.frames_produced,
            st.batches.len(),
            st.train_steps_done,
            st.scores.len()
        );
        println!(
            "   cpu util {:.1}%  gpu util {:.1}%  DOA_res(meas) {}",
            rep.cpu_utilization * 100.0,
            rep.gpu_utilization * 100.0,
            rep.doa_res
        );
        // Loss curve (downsampled).
        if st.losses.len() >= 10 {
            print!("   loss curve: ");
            let stride = (st.losses.len() / 8).max(1);
            for (step, loss) in st.losses.iter().step_by(stride) {
                print!("{step}:{loss:.4} ");
            }
            println!();
            // Compare window means (individual steps are noisy across
            // rotating batches).
            let k = (st.losses.len() / 4).max(3);
            let head: f32 =
                st.losses[..k].iter().map(|(_, l)| l).sum::<f32>() / k as f32;
            let tail: f32 = st.losses[st.losses.len() - k..].iter().map(|(_, l)| l).sum::<f32>()
                / k as f32;
            assert!(
                tail < head,
                "training must reduce loss (head mean {head}, tail mean {tail})"
            );
            println!(
                "   loss window mean {head:.4} -> {tail:.4} (improved {:.1}%)",
                (1.0 - tail / head) * 100.0
            );
        }
        if !st.scores.is_empty() {
            let mean = st.scores.iter().sum::<f32>() / st.scores.len() as f32;
            println!("   outlier scores: n={} mean={:.4}", st.scores.len(), mean);
        }
        results.push((mode, rep.makespan, wall));
    }

    let (_, t_seq, _) = results[0];
    let (_, t_async, _) = results[1];
    let i = 1.0 - t_async / t_seq;
    println!("\n== relative improvement I = 1 - tAsync/tSeq = {i:+.3}");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores <= 2 {
        println!(
            "   (host has {cores} core(s): all PJRT compute serializes on one CPU, so\n\
             \u{20}   asynchronous execution cannot mask anything here — note the higher\n\
             \u{20}   utilization% above. The Summit-scale improvement is quantified by\n\
             \u{20}   the virtual-time experiments: `asyncflow experiment table3`.)"
        );
    }
    let (compiles, execs) = svc.handle().stats()?;
    println!("== runtime: {compiles} artifact compilations, {execs} executions (compile cache OK)");
    println!("ddmd_e2e OK — three-layer stack composed (Rust -> PJRT -> Pallas HLO)");
    Ok(())
}
