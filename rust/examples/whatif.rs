//! What-if analysis: use the model as a *design tool* (§8).
//!
//! The paper argues workflow authors need "constructs and tools to
//! assess the performance improvement that an asynchronous
//! implementation would offer" before committing to one. This example
//! sweeps two design axes for DeepDriveMD and reports where
//! asynchronicity stops paying:
//!
//! 1. Simulation TX (longer sims -> more masking headroom);
//! 2. GPUs per node (more GPUs -> higher DOA_res).
//!
//! Run: `cargo run --release --example whatif`

use asyncflow::ddmd::{ddmd_workflow, DdmdConfig};
use asyncflow::engine::{simulate_cfg, ExecutionMode};
use asyncflow::experiments::paper_engine_config;
use asyncflow::model;
use asyncflow::resources::ClusterSpec;
use asyncflow::util::bench::Table;

fn main() {
    let cfg = paper_engine_config(42);

    println!("# Sweep 1: Simulation TX (paper value 340 s)\n");
    let mut t = Table::new(&["sim TX", "WLA", "I predicted", "I measured", "verdict"]);
    for sim_tx in [40.0, 85.0, 170.0, 340.0, 680.0, 1360.0] {
        let mut d = DdmdConfig::paper();
        d.simulation.tx = sim_tx;
        let wf = ddmd_workflow(&d);
        let cluster = ClusterSpec::summit_paper();
        let pred = model::predict(&wf, &cluster);
        let seq = simulate_cfg(&wf, &cluster, ExecutionMode::Sequential, &cfg);
        let asy = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
        let i = asy.improvement_over(&seq);
        t.row(&[
            format!("{sim_tx:.0} s"),
            format!("{}", pred.wla),
            format!("{:+.3}", pred.improvement),
            format!("{i:+.3}"),
            (if i > 0.02 { "go async" } else { "stay sequential" }).to_string(),
        ]);
    }
    t.print();

    println!("\n# Sweep 2: GPUs per node (Summit has 6)\n");
    let mut t = Table::new(&["gpus/node", "DOA_res", "WLA", "I measured"]);
    for gpn in [2, 4, 6, 8, 12] {
        let cluster = ClusterSpec::uniform(format!("summit-{gpn}g"), 16, 168, gpn);
        let wf = ddmd_workflow(&DdmdConfig::paper());
        let pred = model::predict(&wf, &cluster);
        let seq = simulate_cfg(&wf, &cluster, ExecutionMode::Sequential, &cfg);
        let asy = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
        t.row(&[
            format!("{gpn}"),
            format!("{}", pred.doa_res),
            format!("{}", pred.wla),
            format!("{:+.3}", asy.improvement_over(&seq)),
        ]);
    }
    t.print();
    println!(
        "\nReading: masking headroom (long simulations) matters more than raw\n\
         GPU count — exactly the paper's point that WLA alone does not\n\
         guarantee improvement (c-DG1) without TX masking to exploit it."
    );
}
