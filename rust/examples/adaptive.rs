//! Adaptive (task-level) asynchronicity — the paper's future work (§6.1,
//! §8), implemented.
//!
//! The paper's asynchronous mode keeps EnTK stage barriers inside each
//! pipeline, which couples independent chains ("Aggr0 and Train1 can
//! run at the same time" is the motivating example). `Adaptive` drops
//! the barriers: every task set becomes eligible the instant its DAG
//! parents complete. This example quantifies what that buys on all
//! three paper workflows.
//!
//! Run: `cargo run --release --example adaptive`

use asyncflow::engine::{simulate_cfg, ExecutionMode};
use asyncflow::experiments::{experiment_workflows, paper_engine_config};
use asyncflow::util::bench::Table;

fn main() {
    let cfg = paper_engine_config(42);
    let mut table = Table::new(&[
        "workflow",
        "tSeq",
        "tAsync (paper mode)",
        "tAdaptive",
        "I async",
        "I adaptive",
        "adaptive gain",
    ]);
    for (wf, cluster) in experiment_workflows() {
        let seq = simulate_cfg(&wf, &cluster, ExecutionMode::Sequential, &cfg);
        let asy = simulate_cfg(&wf, &cluster, ExecutionMode::Asynchronous, &cfg);
        let ada = simulate_cfg(&wf, &cluster, ExecutionMode::Adaptive, &cfg);
        table.row(&[
            wf.name.clone(),
            format!("{:.0}", seq.makespan),
            format!("{:.0}", asy.makespan),
            format!("{:.0}", ada.makespan),
            format!("{:+.3}", asy.improvement_over(&seq)),
            format!("{:+.3}", ada.improvement_over(&seq)),
            format!("{:+.3}", 1.0 - ada.makespan / asy.makespan),
        ]);
    }
    println!("# Adaptive task-level asynchronicity vs the paper's stage-barrier mode\n");
    table.print();
    println!(
        "\nReading: 'adaptive gain' is the extra makespan reduction from removing\n\
         intra-pipeline stage barriers — the paper's proposed next step. It is\n\
         bounded above by the critical-path slack the barriers were wasting."
    );
}
